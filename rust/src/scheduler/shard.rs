//! Sharding planner: split one [`ConvLayer`] into independent pieces of
//! work along the paper's own step structure.
//!
//! Three per-layer shard axes (plus the cross-layer pipeline mode):
//!
//! * **Filters** — the TrIM engine executes a layer as `⌈N/P_N⌉ × ⌈M/P_M⌉`
//!   computational steps (eq. (2)): the outer loop walks *filter groups* of
//!   `P_N` filters, and filters never share state — each core owns one
//!   filter and one psum buffer (Fig. 6). Filter groups are therefore the
//!   natural shard unit for a farm of engines (the multi-fabric scaling of
//!   the 3D-TrIM follow-up): give each engine a contiguous run of whole
//!   filter groups and the union of the shard ofmaps is bit-identical to a
//!   single-engine run, while the shard access counters partition the
//!   single-engine counters exactly.
//! * **Rows** ([`plan_row_shards`]) — split the *spatial* dimension
//!   instead: contiguous bands of output rows, each engine computing all
//!   `N` filters over its band (the multi-fabric spatial split the 3D-TrIM
//!   follow-up motivates for wide early layers). This is the axis that
//!   saturates a farm on CL1-class layers, where `⌈N/P_N⌉` filter groups
//!   cap filter-shard parallelism below the engine count (VGG-16 CL1 on
//!   the paper engine: 10 groups — an 8+-engine farm is starved on the
//!   filter axis but `H_O = 224` rows split 8 ways evenly). Each band
//!   reads its input slab *including halo rows* shared with the adjacent
//!   band ([`ConvLayer::band_input_rows`]), so band off-chip input reads
//!   sum to the single-engine count plus exactly the halo duplication.
//!
//! * **Hybrid grid** ([`plan_hybrid_shards`]) — cut *both* dimensions at
//!   once: a `g_f × g_r` grid of filter-split × row-band tiles
//!   (`g_f·g_r ≤ engines`). Either single axis caps the farm at
//!   `⌈N/P_N⌉` groups or at the engine count's fit into `H_O` rows; the
//!   grid keeps scaling past both (the Eyeriss-style 2-D tiling of the
//!   row-stationary mapper, applied to TrIM's own step structure) — e.g.
//!   16 engines on a 10-group, 120-row CL1-class layer bound 10× by
//!   filters and 15× by rows, but 16× on the 2×8 grid.
//!
//! Tiled layers (K > K_nat, §V) keep a different *intra*-engine schedule,
//! but filters remain independent there too and a row band is just a
//! shorter layer, so every split stays exact (a hybrid tile is simply the
//! row band of a filter sub-layer).
//!
//! [`ShardMode::Auto`] picks per layer: whichever axis has the better
//! [`ShardPlan::speedup_bound`], rows winning the filter/rows tie on
//! layers whose filter count cannot occupy the farm (`N < engines·P_N`),
//! and the hybrid grid winning only when strictly better than both.

use crate::arch::ArchConfig;
use crate::model::ConvLayer;
use std::ops::Range;

/// How the farm distributes work (see [`crate::scheduler::EngineFarm`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// Split each layer's filters across engines (data-parallel within a
    /// layer); every engine sees every input activation.
    FilterShards,
    /// Pin each layer of a network to an engine and stream images through
    /// (pipeline-parallel across layers); engine `i` runs layers
    /// `i, i+E, …` of the chain.
    LayerPipeline,
    /// Split each layer's output rows across engines (spatial-parallel
    /// within a layer); every engine runs all `N` filters over its band.
    Spatial,
    /// Split each layer across a 2-D filter-group × output-row grid
    /// ([`plan_hybrid_shards`]): farms larger than either single axis
    /// keep scaling (e.g. 16 engines on a 10-group, 120-row layer).
    Hybrid,
    /// Per layer, pick the best of [`ShardMode::FilterShards`],
    /// [`ShardMode::Spatial`] and [`ShardMode::Hybrid`] by
    /// [`ShardPlan::speedup_bound`]: rows win the filter/rows tie on
    /// `N < engines·P_N` layers, and the hybrid grid is chosen only when
    /// its bound is *strictly* higher than both single axes.
    Auto,
}

impl ShardMode {
    /// CLI-facing name (`--shard filter|pipeline|spatial|hybrid|auto`).
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::FilterShards => "filter",
            Self::LayerPipeline => "pipeline",
            Self::Spatial => "spatial",
            Self::Hybrid => "hybrid",
            Self::Auto => "auto",
        }
    }
}

impl std::fmt::Display for ShardMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.pad(self.as_str())
    }
}

impl std::str::FromStr for ShardMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "filter" | "filters" | "shards" => Ok(Self::FilterShards),
            "pipeline" | "layers" => Ok(Self::LayerPipeline),
            "spatial" | "rows" => Ok(Self::Spatial),
            "hybrid" | "grid" => Ok(Self::Hybrid),
            "auto" => Ok(Self::Auto),
            other => Err(anyhow::anyhow!(
                "unknown shard mode {other:?} (expected filter|pipeline|spatial|hybrid|auto)"
            )),
        }
    }
}

/// Which dimension a [`ShardPlan`] cuts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardAxis {
    /// Shards are contiguous filter ranges (each over all output rows).
    Filters,
    /// Shards are contiguous output-row bands (each over all filters).
    Rows,
    /// Shards are filter-range × row-band tiles of a 2-D grid.
    Hybrid,
}

impl ShardAxis {
    /// Short display name (the `trim farm` per-layer table).
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Filters => "filters",
            Self::Rows => "rows",
            Self::Hybrid => "hybrid",
        }
    }
}

/// One engine's piece of a layer: a filter range × an output-row range.
/// Filter-axis shards cover all rows; row-axis shards cover all filters.
/// Filter boundaries are aligned to `P_N`-filter group boundaries (except
/// for the tail of the layer).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    /// Shard index (== the engine it is dispatched to).
    pub index: usize,
    /// Filters `[start, end)` of the layer this shard computes.
    pub filters: Range<usize>,
    /// Whole filter groups of `P_N` covered by this shard.
    pub groups: usize,
    /// Output rows `[start, end)` of the layer this shard computes.
    pub rows: Range<usize>,
}

/// The per-layer shard assignment.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// The dimension this plan cuts.
    pub axis: ShardAxis,
    /// One entry per engine that received work (`len() ≤ engines`).
    pub shards: Vec<Shard>,
    /// Total filter groups in the layer: `⌈N/P_N⌉`.
    pub filter_groups: usize,
    /// The group size filter splits are aligned to (`P_N` of the engine).
    pub p_n: usize,
    /// Total output rows in the layer (`H_O`).
    pub rows: usize,
    /// Shard-grid dimensions `(filter splits, row splits)`: `(len, 1)`
    /// for the filter axis, `(1, len)` for rows, `(g_f, g_r)` for the
    /// hybrid grid. `grid.0 · grid.1 == shards.len()` always.
    pub grid: (usize, usize),
}

impl ShardPlan {
    /// Upper bound on the parallel speedup this split can deliver, in the
    /// plan's own work unit: whole-layer filter groups over the largest
    /// shard's groups (filter axis), whole-layer output rows over the
    /// largest band (row axis), or — on the hybrid grid — whole-layer
    /// (groups × rows) cells over the largest tile's cells, which reduces
    /// to the 1-D formulas when one grid dimension is 1. One metric
    /// across all three axes, so [`ShardMode::Auto`] can compare them
    /// directly.
    pub fn speedup_bound(&self) -> f64 {
        match self.axis {
            ShardAxis::Filters => {
                let largest = self.shards.iter().map(|s| s.groups).max().unwrap_or(1);
                self.filter_groups as f64 / largest as f64
            }
            ShardAxis::Rows => {
                let largest = self.shards.iter().map(|s| s.rows.len()).max().unwrap_or(1);
                self.rows as f64 / largest as f64
            }
            ShardAxis::Hybrid => {
                let largest =
                    self.shards.iter().map(|s| s.groups * s.rows.len()).max().unwrap_or(1);
                (self.filter_groups * self.rows) as f64 / largest as f64
            }
        }
    }
}

/// Split `n_units` contiguous work units across at most `engines` shards,
/// as evenly as possible (counts differ by at most one).
fn balanced_split(n_units: usize, engines: usize) -> Vec<Range<usize>> {
    let n_shards = engines.min(n_units);
    let base = n_units / n_shards;
    let extra = n_units % n_shards;
    let mut out = Vec::with_capacity(n_shards);
    let mut at = 0usize;
    for index in 0..n_shards {
        let take = base + usize::from(index < extra);
        out.push(at..at + take);
        at += take;
    }
    out
}

/// Split `n_units` contiguous work units into shares proportional to
/// `weights` (largest-remainder rounding, ties to the lower index), with
/// every shard kept non-empty while units allow — the cost-proportional
/// sizing hook for heterogeneous / gray-degraded farms: an engine
/// observed at half speed carries half the weight and receives half the
/// units. Uniform weights reproduce [`balanced_split`] exactly, so every
/// planner invariant (coverage, contiguity, ≤1 imbalance) degrades to
/// the equal-split case.
fn weighted_split(n_units: usize, weights: &[f64]) -> Vec<Range<usize>> {
    let n_shards = weights.len().min(n_units).max(1);
    // Sanitize: non-finite or non-positive weights get a small floor so
    // a pathological health reading can shrink a share, never erase the
    // engine from the plan.
    let w: Vec<f64> = weights
        .iter()
        .take(n_shards)
        .map(|x| if x.is_finite() && *x > 0.0 { *x } else { 1e-3 })
        .collect();
    let lo = w.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = w.iter().copied().fold(0.0f64, f64::max);
    if n_shards <= 1 || hi - lo <= 1e-9 * hi {
        return balanced_split(n_units, n_shards);
    }
    let total: f64 = w.iter().sum();
    let mut share = vec![0usize; n_shards];
    let mut rem: Vec<(usize, f64)> = Vec::with_capacity(n_shards);
    let mut assigned = 0usize;
    for (i, wi) in w.iter().enumerate() {
        let quota = n_units as f64 * wi / total;
        let base = (quota.floor() as usize).min(n_units);
        share[i] = base;
        assigned += base;
        rem.push((i, quota - base as f64));
    }
    // Largest remainder first; equal remainders go to the lower index
    // (matching balanced_split's earliest-shards-get-the-extra layout).
    rem.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0)));
    for (i, _) in rem.iter().cycle().take(n_units.saturating_sub(assigned)) {
        share[*i] += 1;
    }
    // Keep every shard non-empty: steal from the largest share (which
    // must hold > 1 unit because n_units ≥ n_shards here).
    loop {
        let Some(empty) = share.iter().position(|&s| s == 0) else { break };
        let biggest = share
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .map(|(i, _)| i)
            .unwrap_or(0);
        if share[biggest] <= 1 {
            break;
        }
        share[biggest] -= 1;
        share[empty] += 1;
    }
    let mut out = Vec::with_capacity(n_shards);
    let mut at = 0usize;
    for take in share {
        out.push(at..at + take);
        at += take;
    }
    out
}

/// Split `layer` into at most `engines` filter shards on `P_N`-group
/// boundaries, balancing whole groups as evenly as possible.
///
/// Guarantees (property-tested in tests/scheduler_farm.rs):
/// * shards are non-empty, disjoint, contiguous and cover `0..N`;
/// * every shard boundary except the layer end is a multiple of `P_N`;
/// * shard group counts differ by at most one;
/// * `shards.len() == min(engines, ⌈N/P_N⌉)`.
pub fn plan_filter_shards(arch: &ArchConfig, layer: &ConvLayer, engines: usize) -> ShardPlan {
    assert!(engines >= 1, "need at least one engine");
    assert!(layer.n >= 1, "layer has no filters");
    let p_n = arch.p_n;
    let h_o = layer.h_o();
    let filter_groups = layer.n.div_ceil(p_n);
    let shards = balanced_split(filter_groups, engines)
        .into_iter()
        .enumerate()
        .map(|(index, g)| Shard {
            index,
            filters: g.start * p_n..(g.end * p_n).min(layer.n),
            groups: g.len(),
            rows: 0..h_o,
        })
        .collect::<Vec<_>>();
    let grid = (shards.len(), 1);
    ShardPlan { axis: ShardAxis::Filters, shards, filter_groups, p_n, rows: h_o, grid }
}

/// Split `layer` into at most `engines` contiguous output-row bands; each
/// shard computes all `N` filters over its band.
///
/// Guarantees (property-tested in tests/scheduler_farm.rs):
/// * bands are non-empty, disjoint, contiguous and cover `0..H_O`;
/// * band heights differ by at most one;
/// * `shards.len() == min(engines, H_O)`.
pub fn plan_row_shards(arch: &ArchConfig, layer: &ConvLayer, engines: usize) -> ShardPlan {
    assert!(engines >= 1, "need at least one engine");
    let h_o = layer.h_o();
    assert!(h_o >= 1, "layer has no output rows");
    let filter_groups = layer.n.div_ceil(arch.p_n);
    let shards = balanced_split(h_o, engines)
        .into_iter()
        .enumerate()
        .map(|(index, rows)| Shard {
            index,
            filters: 0..layer.n,
            groups: filter_groups,
            rows,
        })
        .collect::<Vec<_>>();
    let grid = (1, shards.len());
    ShardPlan { axis: ShardAxis::Rows, shards, filter_groups, p_n: arch.p_n, rows: h_o, grid }
}

/// Split `layer` across a 2-D grid of at most `engines` filter-group ×
/// output-row tiles: `g_f` contiguous filter splits (on `P_N`-group
/// boundaries, like [`plan_filter_shards`]) × `g_r` contiguous row bands
/// (like [`plan_row_shards`]), with `g_f·g_r ≤ engines`. The grid is the
/// `(g_f, g_r)` pair maximising the 2-D [`ShardPlan::speedup_bound`]
/// (row-heavier grids win ties), which is what lets farms bigger than
/// either single axis keep scaling — the Eyeriss-style 2-D tiling axis
/// the ROADMAP names.
///
/// Guarantees (property-tested in tests/scheduler_farm.rs):
/// * the tiles partition the full filter-range × row-range rectangle:
///   every (filter, output row) pair is covered by exactly one shard;
/// * filter splits are `P_N`-group aligned (except the layer tail) and
///   balanced within one group; row bands are balanced within one row;
/// * `shards.len() == grid.0 · grid.1 ≤ engines`, indexed row-major
///   (filter split outer, row band inner);
/// * with `grid == (1, g)` or `(g, 1)` the tiles coincide with the pure
///   row/filter plans, so the hybrid bound is never below either axis.
pub fn plan_hybrid_shards(arch: &ArchConfig, layer: &ConvLayer, engines: usize) -> ShardPlan {
    assert!(engines >= 1, "need at least one engine");
    assert!(layer.n >= 1, "layer has no filters");
    let h_o = layer.h_o();
    assert!(h_o >= 1, "layer has no output rows");
    let p_n = arch.p_n;
    let filter_groups = layer.n.div_ceil(p_n);
    // Exhaustive grid search (both dims are tiny): for each filter-split
    // count, rows get the whole remaining engine budget — the bound is
    // monotone in g_r, so nothing smaller can win.
    let bound_of = |g_f: usize, g_r: usize| -> f64 {
        let gmax = filter_groups.div_ceil(g_f.min(filter_groups));
        let rmax = h_o.div_ceil(g_r.min(h_o));
        (filter_groups as f64 / gmax as f64) * (h_o as f64 / rmax as f64)
    };
    let mut best = (1usize, engines.min(h_o));
    let mut best_bound = bound_of(best.0, best.1);
    for g_f in 2..=engines.min(filter_groups) {
        let g_r = (engines / g_f).min(h_o).max(1);
        let b = bound_of(g_f, g_r);
        if b > best_bound + 1e-12 {
            best = (g_f, g_r);
            best_bound = b;
        }
    }
    let fsplits = balanced_split(filter_groups, best.0);
    let rsplits = balanced_split(h_o, best.1);
    let mut shards = Vec::with_capacity(fsplits.len() * rsplits.len());
    for g in &fsplits {
        for rows in &rsplits {
            shards.push(Shard {
                index: shards.len(),
                filters: g.start * p_n..(g.end * p_n).min(layer.n),
                groups: g.len(),
                rows: rows.clone(),
            });
        }
    }
    let grid = (fsplits.len(), rsplits.len());
    ShardPlan { axis: ShardAxis::Hybrid, shards, filter_groups, p_n, rows: h_o, grid }
}

/// Plan one layer under `mode`. `Auto` compares the three per-layer axes
/// on [`ShardPlan::speedup_bound`]: the filter/rows tie goes to rows
/// exactly when the layer's filters cannot occupy the farm
/// (`N < engines·P_N` — the CL1-class shape spatial sharding exists for),
/// and the hybrid grid wins only when its bound is *strictly* above both
/// single axes (a pure axis is the simpler plan at equal bound — fewer
/// halo rows, contiguous stitches). [`ShardMode::LayerPipeline`] is a
/// cross-layer mode and has no per-layer plan.
pub fn plan_shards(arch: &ArchConfig, layer: &ConvLayer, engines: usize, mode: ShardMode) -> ShardPlan {
    match mode {
        ShardMode::FilterShards => plan_filter_shards(arch, layer, engines),
        ShardMode::Spatial => plan_row_shards(arch, layer, engines),
        ShardMode::Hybrid => plan_hybrid_shards(arch, layer, engines),
        ShardMode::Auto => {
            let by_filters = plan_filter_shards(arch, layer, engines);
            let by_rows = plan_row_shards(arch, layer, engines);
            let (bf, br) = (by_filters.speedup_bound(), by_rows.speedup_bound());
            let pure = if br > bf || (br == bf && layer.n < engines * arch.p_n) {
                by_rows
            } else {
                by_filters
            };
            let by_grid = plan_hybrid_shards(arch, layer, engines);
            if by_grid.speedup_bound() > pure.speedup_bound() + 1e-9 {
                by_grid
            } else {
                pure
            }
        }
        ShardMode::LayerPipeline => {
            panic!("LayerPipeline is a cross-layer mode; it has no per-layer shard plan")
        }
    }
}

/// [`plan_filter_shards`] with cost-proportional group counts: shard `i`
/// receives filter groups in proportion to `weights[i]` (one weight per
/// engine; uniform weights reproduce the equal split exactly). Shard
/// boundaries stay `P_N`-group aligned and the shards still partition
/// `0..N` — only the *sizes* change, so ABFT verification and stitching
/// are untouched.
pub fn plan_filter_shards_weighted(arch: &ArchConfig, layer: &ConvLayer, weights: &[f64]) -> ShardPlan {
    assert!(!weights.is_empty(), "need at least one engine weight");
    assert!(layer.n >= 1, "layer has no filters");
    let p_n = arch.p_n;
    let h_o = layer.h_o();
    let filter_groups = layer.n.div_ceil(p_n);
    let shards = weighted_split(filter_groups, weights)
        .into_iter()
        .enumerate()
        .map(|(index, g)| Shard {
            index,
            filters: g.start * p_n..(g.end * p_n).min(layer.n),
            groups: g.len(),
            rows: 0..h_o,
        })
        .collect::<Vec<_>>();
    let grid = (shards.len(), 1);
    ShardPlan { axis: ShardAxis::Filters, shards, filter_groups, p_n, rows: h_o, grid }
}

/// [`plan_row_shards`] with cost-proportional band heights: shard `i`
/// receives output rows in proportion to `weights[i]`.
pub fn plan_row_shards_weighted(arch: &ArchConfig, layer: &ConvLayer, weights: &[f64]) -> ShardPlan {
    assert!(!weights.is_empty(), "need at least one engine weight");
    let h_o = layer.h_o();
    assert!(h_o >= 1, "layer has no output rows");
    let filter_groups = layer.n.div_ceil(arch.p_n);
    let shards = weighted_split(h_o, weights)
        .into_iter()
        .enumerate()
        .map(|(index, rows)| Shard { index, filters: 0..layer.n, groups: filter_groups, rows })
        .collect::<Vec<_>>();
    let grid = (1, shards.len());
    ShardPlan { axis: ShardAxis::Rows, shards, filter_groups, p_n: arch.p_n, rows: h_o, grid }
}

/// Cost-proportional variant of [`plan_shards`]: one weight per engine
/// (the farm feeds `EngineHealthMap` speed weights — a slow engine gets
/// a proportionally smaller filter-group run or row band). The axis
/// decision is made by the uniform planner first, then the chosen 1-D
/// axis is re-split by weight; hybrid grids keep the uniform 2-D tiling
/// (a weighted grid would need a per-engine tile *assignment*, which the
/// work-stealing injector deliberately leaves emergent). Uniform weights
/// return exactly the uniform plan.
pub fn plan_shards_weighted(
    arch: &ArchConfig,
    layer: &ConvLayer,
    weights: &[f64],
    mode: ShardMode,
) -> ShardPlan {
    assert!(!weights.is_empty(), "need at least one engine weight");
    let engines = weights.len();
    let uniform = plan_shards(arch, layer, engines, mode);
    let lo = weights.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = weights.iter().copied().fold(0.0f64, f64::max);
    if hi - lo <= 1e-9 * hi.max(1e-12) {
        return uniform;
    }
    match uniform.axis {
        ShardAxis::Filters => plan_filter_shards_weighted(arch, layer, weights),
        ShardAxis::Rows => plan_row_shards_weighted(arch, layer, weights),
        ShardAxis::Hybrid => uniform,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(n: usize) -> ConvLayer {
        ConvLayer::new("s", 8, 3, 2, n, 1, 1)
    }

    fn check_invariants(plan: &ShardPlan, n: usize, engines: usize) {
        assert_eq!(plan.axis, ShardAxis::Filters);
        assert_eq!(plan.shards.len(), engines.min(plan.filter_groups));
        let mut next = 0usize;
        for (i, s) in plan.shards.iter().enumerate() {
            assert_eq!(s.index, i);
            assert_eq!(s.filters.start, next, "contiguous");
            assert!(s.filters.start < s.filters.end, "non-empty");
            if s.filters.end != n {
                assert_eq!(s.filters.end % plan.p_n, 0, "group-aligned");
            }
            assert_eq!(s.rows, 0..plan.rows, "filter shards cover all rows");
            next = s.filters.end;
        }
        assert_eq!(next, n, "covers all filters");
        let gmin = plan.shards.iter().map(|s| s.groups).min().unwrap();
        let gmax = plan.shards.iter().map(|s| s.groups).max().unwrap();
        assert!(gmax - gmin <= 1, "balanced");
    }

    #[test]
    fn splits_on_group_boundaries() {
        let cfg = ArchConfig::small(3, 2, 2); // P_N = 2
        for n in [1, 2, 3, 5, 7, 8, 64] {
            for engines in [1, 2, 3, 4, 9] {
                let plan = plan_filter_shards(&cfg, &layer(n), engines);
                check_invariants(&plan, n, engines);
            }
        }
    }

    #[test]
    fn paper_engine_vgg_cl2_split() {
        // VGG-16 CL2: N = 64 on P_N = 7 → 10 filter groups; 4 engines get
        // 3+3+2+2 groups.
        let cfg = ArchConfig::paper_engine();
        let l = ConvLayer::new("CL2", 224, 3, 64, 64, 1, 1);
        let plan = plan_filter_shards(&cfg, &l, 4);
        assert_eq!(plan.filter_groups, 10);
        let groups: Vec<usize> = plan.shards.iter().map(|s| s.groups).collect();
        assert_eq!(groups, vec![3, 3, 2, 2]);
        assert_eq!(plan.shards[0].filters, 0..21);
        assert_eq!(plan.shards[3].filters, 56..64);
        assert!((plan.speedup_bound() - 10.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn more_engines_than_groups_caps_shards() {
        let cfg = ArchConfig::small(3, 2, 4); // P_N = 4
        let plan = plan_filter_shards(&cfg, &layer(6), 8);
        assert_eq!(plan.filter_groups, 2);
        assert_eq!(plan.shards.len(), 2);
        assert_eq!(plan.shards[0].filters, 0..4);
        assert_eq!(plan.shards[1].filters, 4..6);
    }

    #[test]
    fn row_shards_cover_and_balance() {
        let cfg = ArchConfig::small(3, 2, 2);
        for h_w in [8usize, 9, 10, 13] {
            let l = ConvLayer::new("r", h_w, 3, 2, 5, 1, 1);
            for engines in [1usize, 2, 3, 4, 64] {
                let plan = plan_row_shards(&cfg, &l, engines);
                assert_eq!(plan.axis, ShardAxis::Rows);
                assert_eq!(plan.rows, l.h_o());
                assert_eq!(plan.shards.len(), engines.min(l.h_o()));
                let mut next = 0usize;
                for (i, s) in plan.shards.iter().enumerate() {
                    assert_eq!(s.index, i);
                    assert_eq!(s.rows.start, next, "contiguous");
                    assert!(!s.rows.is_empty(), "non-empty");
                    assert_eq!(s.filters, 0..l.n, "row shards cover all filters");
                    next = s.rows.end;
                }
                assert_eq!(next, l.h_o(), "covers all rows");
                let bmin = plan.shards.iter().map(|s| s.rows.len()).min().unwrap();
                let bmax = plan.shards.iter().map(|s| s.rows.len()).max().unwrap();
                assert!(bmax - bmin <= 1, "balanced");
                assert!((plan.speedup_bound() - plan.rows as f64 / bmax as f64).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn paper_engine_vgg_cl1_rows_beat_filters() {
        // VGG-16 CL1 (N = 64, H_O = 224) on the paper engine: only 10
        // filter groups, so an 8-engine farm is capped at 10/2 = 5× on the
        // filter axis while 224 rows split 8 ways bound 8×. Auto must pick
        // rows.
        let cfg = ArchConfig::paper_engine();
        let cl1 = ConvLayer::new("CL1", 224, 3, 3, 64, 1, 1);
        let f = plan_filter_shards(&cfg, &cl1, 8);
        let r = plan_row_shards(&cfg, &cl1, 8);
        assert!((f.speedup_bound() - 5.0).abs() < 1e-9);
        assert!((r.speedup_bound() - 8.0).abs() < 1e-9);
        let auto = plan_shards(&cfg, &cl1, 8, ShardMode::Auto);
        assert_eq!(auto.axis, ShardAxis::Rows);
        assert!((auto.speedup_bound() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn auto_tie_breaks_toward_rows_only_on_narrow_layers() {
        let cfg = ArchConfig::small(3, 2, 2); // P_N = 2
        // N = 4 → 2 groups; H_O = 8. Two engines: both axes bound 2×, and
        // N = 4 == engines·P_N, so the tie goes to the filter axis.
        let wide = ConvLayer::new("w", 8, 3, 2, 4, 1, 1);
        assert_eq!(plan_shards(&cfg, &wide, 2, ShardMode::Auto).axis, ShardAxis::Filters);
        // N = 2 → 1 group; a 1-engine farm ties at 1× on both axes, and
        // N = 2 < 1·2 is false → filters; with 2 engines rows bound 2× > 1×.
        let narrow = ConvLayer::new("n", 8, 3, 2, 2, 1, 1);
        assert_eq!(plan_shards(&cfg, &narrow, 2, ShardMode::Auto).axis, ShardAxis::Rows);
        // Explicit modes pass through.
        assert_eq!(plan_shards(&cfg, &wide, 2, ShardMode::Spatial).axis, ShardAxis::Rows);
        assert_eq!(plan_shards(&cfg, &wide, 2, ShardMode::FilterShards).axis, ShardAxis::Filters);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!("filter".parse::<ShardMode>().unwrap(), ShardMode::FilterShards);
        assert_eq!("pipeline".parse::<ShardMode>().unwrap(), ShardMode::LayerPipeline);
        assert_eq!("spatial".parse::<ShardMode>().unwrap(), ShardMode::Spatial);
        assert_eq!("rows".parse::<ShardMode>().unwrap(), ShardMode::Spatial);
        assert_eq!("hybrid".parse::<ShardMode>().unwrap(), ShardMode::Hybrid);
        assert_eq!("grid".parse::<ShardMode>().unwrap(), ShardMode::Hybrid);
        assert_eq!("auto".parse::<ShardMode>().unwrap(), ShardMode::Auto);
        let err = "bogus".parse::<ShardMode>().unwrap_err().to_string();
        assert!(err.contains("filter|pipeline|spatial|hybrid|auto"), "error lists every mode: {err}");
        assert_eq!(ShardMode::Spatial.to_string(), "spatial");
        assert_eq!(ShardMode::Hybrid.to_string(), "hybrid");
        assert_eq!(ShardMode::Auto.as_str(), "auto");
        assert_eq!(ShardAxis::Hybrid.as_str(), "hybrid");
    }

    #[test]
    fn hybrid_grid_partitions_the_layer() {
        // Every (filter, output row) cell is covered by exactly one tile;
        // filter splits stay group-aligned; grid dims match shards.
        let cfg = ArchConfig::small(3, 2, 2); // P_N = 2
        for (n, hw, engines) in [(4usize, 8usize, 4usize), (10, 15, 6), (7, 9, 12), (2, 20, 5)] {
            let l = ConvLayer::new("h", hw, 3, 2, n, 1, 1);
            let plan = plan_hybrid_shards(&cfg, &l, engines);
            assert_eq!(plan.axis, ShardAxis::Hybrid);
            let (g_f, g_r) = plan.grid;
            assert_eq!(plan.shards.len(), g_f * g_r);
            assert!(g_f * g_r <= engines);
            let mut covered = vec![0u32; n * l.h_o()];
            for (i, s) in plan.shards.iter().enumerate() {
                assert_eq!(s.index, i);
                assert!(!s.filters.is_empty() && !s.rows.is_empty());
                if s.filters.end != n {
                    assert_eq!(s.filters.end % plan.p_n, 0, "group-aligned tail");
                }
                if s.filters.start != 0 {
                    assert_eq!(s.filters.start % plan.p_n, 0, "group-aligned head");
                }
                for f in s.filters.clone() {
                    for r in s.rows.clone() {
                        covered[f * l.h_o() + r] += 1;
                    }
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "exact cover: n={n} hw={hw} e={engines}");
            // The grid bound is never below either pure axis.
            let bf = plan_filter_shards(&cfg, &l, engines).speedup_bound();
            let br = plan_row_shards(&cfg, &l, engines).speedup_bound();
            assert!(plan.speedup_bound() >= bf.max(br) - 1e-9, "n={n} hw={hw} e={engines}");
        }
    }

    #[test]
    fn weighted_split_uniform_weights_reproduce_balanced_split() {
        for n_units in [1usize, 2, 5, 7, 10, 64, 224] {
            for engines in [1usize, 2, 3, 4, 8, 16] {
                let uniform = vec![1.0; engines];
                assert_eq!(
                    weighted_split(n_units, &uniform),
                    balanced_split(n_units, engines),
                    "n={n_units} e={engines}"
                );
            }
        }
    }

    #[test]
    fn weighted_split_is_exact_cover_monotone_and_proportional() {
        let cases: Vec<(usize, Vec<f64>)> = vec![
            (10, vec![2.0, 1.0]),
            (224, vec![4.0, 2.0, 1.0, 1.0]),
            (9, vec![1.0, 1.0, 0.25]),
            (16, vec![0.5, 8.0, 2.0, 1.0]),
            (5, vec![10.0, 0.1, 0.1, 0.1, 0.1]),
            (3, vec![1.0, 3.0, 1.0, 2.0, 1.0]), // more engines than units
        ];
        for (n_units, w) in cases {
            let spans = weighted_split(n_units, &w);
            assert_eq!(spans.len(), w.len().min(n_units));
            let mut next = 0usize;
            for s in &spans {
                assert_eq!(s.start, next, "contiguous");
                assert!(!s.is_empty(), "non-empty: n={n_units} w={w:?}");
                next = s.end;
            }
            assert_eq!(next, n_units, "exact cover: n={n_units} w={w:?}");
            // Monotone: a strictly larger weight never gets fewer units.
            for i in 0..spans.len() {
                for j in 0..spans.len() {
                    if w[i] > w[j] * (1.0 + 1e-9) {
                        assert!(
                            spans[i].len() >= spans[j].len(),
                            "weight {} got {} units, weight {} got {}: n={n_units} w={w:?}",
                            w[i],
                            spans[i].len(),
                            w[j],
                            spans[j].len()
                        );
                    }
                }
            }
            // Proportional within rounding: each share is within one unit
            // of its real-valued quota (largest-remainder guarantee),
            // except where the non-empty floor interferes.
            let total: f64 = w[..spans.len()].iter().sum();
            for (i, s) in spans.iter().enumerate() {
                let quota = n_units as f64 * w[i] / total;
                assert!(
                    (s.len() as f64 - quota).abs() <= 1.0 + 1e-9 || s.len() == 1,
                    "share {} vs quota {quota}: n={n_units} w={w:?}",
                    s.len()
                );
            }
        }
    }

    #[test]
    fn weighted_planners_shrink_the_slow_engines_share() {
        let cfg = ArchConfig::paper_engine(); // P_N = 7
        let l = ConvLayer::new("CL2w", 224, 3, 64, 64, 1, 1); // 10 groups
        // Engine 3 observed 4× slow → quarter weight → smaller share.
        let w = vec![1.0, 1.0, 1.0, 0.25];
        let plan = plan_filter_shards_weighted(&cfg, &l, &w);
        assert_eq!(plan.shards.iter().map(|s| s.groups).sum::<usize>(), 10);
        assert!(
            plan.shards[3].groups < plan.shards[0].groups,
            "slow engine kept an equal share: {:?}",
            plan.shards.iter().map(|s| s.groups).collect::<Vec<_>>()
        );
        // Boundaries stay group-aligned and cover 0..N.
        let mut next = 0usize;
        for s in &plan.shards {
            assert_eq!(s.filters.start, next);
            if s.filters.end != l.n {
                assert_eq!(s.filters.end % plan.p_n, 0);
            }
            next = s.filters.end;
        }
        assert_eq!(next, l.n);
        // Row planner: same story on the spatial axis.
        let rplan = plan_row_shards_weighted(&cfg, &l, &w);
        assert_eq!(rplan.shards.iter().map(|s| s.rows.len()).sum::<usize>(), l.h_o());
        assert!(rplan.shards[3].rows.len() < rplan.shards[0].rows.len());
        // plan_shards_weighted with uniform weights is byte-identical to
        // the uniform planner across modes.
        for mode in [ShardMode::FilterShards, ShardMode::Spatial, ShardMode::Hybrid, ShardMode::Auto] {
            let a = plan_shards_weighted(&cfg, &l, &[1.0; 4], mode);
            let b = plan_shards(&cfg, &l, 4, mode);
            assert_eq!(a.shards, b.shards, "mode {mode:?}");
            assert_eq!(a.axis, b.axis);
        }
    }

    // The acceptance geometry (10 groups × 120 rows on 16 engines:
    // filters 10×, rows 15×, the 2×8 grid 16×, auto → hybrid; 8 engines
    // stay on rows) is pinned once, planner + farm together, in
    // tests/scheduler_farm.rs::cl1_class_16_engines_auto_selects_hybrid.
}
