//! Sharding planner: split one [`ConvLayer`] into independent pieces of
//! work along the paper's own step structure.
//!
//! The TrIM engine executes a layer as `⌈N/P_N⌉ × ⌈M/P_M⌉` computational
//! steps (eq. (2)): the outer loop walks *filter groups* of `P_N` filters,
//! and filters never share state — each core owns one filter and one psum
//! buffer (Fig. 6). Filter groups are therefore the natural shard unit for
//! a farm of engines (the multi-fabric scaling of the 3D-TrIM follow-up):
//! give each engine a contiguous run of whole filter groups and the union
//! of the shard ofmaps is bit-identical to a single-engine run, while the
//! shard access counters partition the single-engine counters exactly.
//!
//! Tiled layers (K > K_nat, §V) keep a different *intra*-engine schedule,
//! but filters remain independent there too, so the same filter-aligned
//! split stays exact.

use crate::arch::ArchConfig;
use crate::model::ConvLayer;
use std::ops::Range;

/// How the farm distributes work (see [`crate::scheduler::EngineFarm`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// Split each layer's filters across engines (data-parallel within a
    /// layer); every engine sees every input activation.
    FilterShards,
    /// Pin each layer of a network to an engine and stream images through
    /// (pipeline-parallel across layers); engine `i` runs layers
    /// `i, i+E, …` of the chain.
    LayerPipeline,
}

impl std::str::FromStr for ShardMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "filter" | "filters" | "shards" => Ok(Self::FilterShards),
            "pipeline" | "layers" => Ok(Self::LayerPipeline),
            other => Err(anyhow::anyhow!("unknown shard mode {other:?} (expected filter|pipeline)")),
        }
    }
}

/// One engine's piece of a layer: a contiguous filter range, aligned to
/// `P_N`-filter group boundaries (except for the tail of the layer).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shard {
    /// Shard index (== the engine it is dispatched to).
    pub index: usize,
    /// Filters `[start, end)` of the layer this shard computes.
    pub filters: Range<usize>,
    /// Whole filter groups of `P_N` covered by this shard.
    pub groups: usize,
}

/// The per-layer shard assignment.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// One entry per engine that received work (`len() ≤ engines`).
    pub shards: Vec<Shard>,
    /// Total filter groups in the layer: `⌈N/P_N⌉`.
    pub filter_groups: usize,
    /// The group size the split is aligned to (`P_N` of the engine).
    pub p_n: usize,
}

impl ShardPlan {
    /// Upper bound on the parallel speedup this split can deliver
    /// (whole-layer groups over the largest shard's groups).
    pub fn speedup_bound(&self) -> f64 {
        let largest = self.shards.iter().map(|s| s.groups).max().unwrap_or(1);
        self.filter_groups as f64 / largest as f64
    }
}

/// Split `layer` into at most `engines` filter shards on `P_N`-group
/// boundaries, balancing whole groups as evenly as possible.
///
/// Guarantees (property-tested in tests/scheduler_farm.rs):
/// * shards are non-empty, disjoint, contiguous and cover `0..N`;
/// * every shard boundary except the layer end is a multiple of `P_N`;
/// * shard group counts differ by at most one;
/// * `shards.len() == min(engines, ⌈N/P_N⌉)`.
pub fn plan_filter_shards(arch: &ArchConfig, layer: &ConvLayer, engines: usize) -> ShardPlan {
    assert!(engines >= 1, "need at least one engine");
    assert!(layer.n >= 1, "layer has no filters");
    let p_n = arch.p_n;
    let filter_groups = layer.n.div_ceil(p_n);
    let n_shards = engines.min(filter_groups);
    let base = filter_groups / n_shards;
    let extra = filter_groups % n_shards;
    let mut shards = Vec::with_capacity(n_shards);
    let mut group0 = 0usize;
    for index in 0..n_shards {
        let groups = base + usize::from(index < extra);
        let start = group0 * p_n;
        let end = ((group0 + groups) * p_n).min(layer.n);
        shards.push(Shard { index, filters: start..end, groups });
        group0 += groups;
    }
    ShardPlan { shards, filter_groups, p_n }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(n: usize) -> ConvLayer {
        ConvLayer::new("s", 8, 3, 2, n, 1, 1)
    }

    fn check_invariants(plan: &ShardPlan, n: usize, engines: usize) {
        assert_eq!(plan.shards.len(), engines.min(plan.filter_groups));
        let mut next = 0usize;
        for (i, s) in plan.shards.iter().enumerate() {
            assert_eq!(s.index, i);
            assert_eq!(s.filters.start, next, "contiguous");
            assert!(s.filters.start < s.filters.end, "non-empty");
            if s.filters.end != n {
                assert_eq!(s.filters.end % plan.p_n, 0, "group-aligned");
            }
            next = s.filters.end;
        }
        assert_eq!(next, n, "covers all filters");
        let gmin = plan.shards.iter().map(|s| s.groups).min().unwrap();
        let gmax = plan.shards.iter().map(|s| s.groups).max().unwrap();
        assert!(gmax - gmin <= 1, "balanced");
    }

    #[test]
    fn splits_on_group_boundaries() {
        let cfg = ArchConfig::small(3, 2, 2); // P_N = 2
        for n in [1, 2, 3, 5, 7, 8, 64] {
            for engines in [1, 2, 3, 4, 9] {
                let plan = plan_filter_shards(&cfg, &layer(n), engines);
                check_invariants(&plan, n, engines);
            }
        }
    }

    #[test]
    fn paper_engine_vgg_cl2_split() {
        // VGG-16 CL2: N = 64 on P_N = 7 → 10 filter groups; 4 engines get
        // 3+3+2+2 groups.
        let cfg = ArchConfig::paper_engine();
        let l = ConvLayer::new("CL2", 224, 3, 64, 64, 1, 1);
        let plan = plan_filter_shards(&cfg, &l, 4);
        assert_eq!(plan.filter_groups, 10);
        let groups: Vec<usize> = plan.shards.iter().map(|s| s.groups).collect();
        assert_eq!(groups, vec![3, 3, 2, 2]);
        assert_eq!(plan.shards[0].filters, 0..21);
        assert_eq!(plan.shards[3].filters, 56..64);
        assert!((plan.speedup_bound() - 10.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn more_engines_than_groups_caps_shards() {
        let cfg = ArchConfig::small(3, 2, 4); // P_N = 4
        let plan = plan_filter_shards(&cfg, &layer(6), 8);
        assert_eq!(plan.filter_groups, 2);
        assert_eq!(plan.shards.len(), 2);
        assert_eq!(plan.shards[0].filters, 0..4);
        assert_eq!(plan.shards[1].filters, 4..6);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!("filter".parse::<ShardMode>().unwrap(), ShardMode::FilterShards);
        assert_eq!("pipeline".parse::<ShardMode>().unwrap(), ShardMode::LayerPipeline);
        assert!("bogus".parse::<ShardMode>().is_err());
    }
}
