//! # TrIM — Triangular Input Movement Systolic Array for CNNs
//!
//! Reproduction of *Sestito, Agwa, Prodromakis, "TrIM, Triangular Input
//! Movement Systolic Array for Convolutional Neural Networks: Architecture
//! and Hardware Implementation"*, IEEE TCSI 2024.
//!
//! The crate is organised as a software twin of the paper's FPGA design:
//!
//! * [`arch`] — the TrIM hardware hierarchy (PE → Slice → Core → Engine)
//!   at two execution tiers behind one API ([`arch::ExecFidelity`]): the
//!   cycle-accurate *register* tier, faithful to Figs. 3–6 (registers,
//!   muxes, shift-register buffers, adder trees and the control FSM
//!   stepped cycle by cycle), and the *fast* tier ([`arch::fastsim`]) —
//!   bit-exact ofmaps from a blocked functional convolution plus
//!   counter-exact stats from the closed-form eq. (2) / Tables I–II
//!   model, orders of magnitude faster per layer.
//! * [`golden`] — integer direct-convolution oracle used to validate the
//!   simulator's numerics.
//! * [`model`] — CNN workload descriptions (VGG-16, AlexNet), kernel tiling
//!   for K > 3, and quantisation helpers.
//! * [`analytics`] — the paper's analytical models: eqs. (1)–(4), the
//!   memory-access models for TrIM / Eyeriss-RS / WS-GeMM, the energy
//!   model, the Fig. 7 design-space sweep and the Table III FPGA cost model.
//! * [`coordinator`] — the L3 runtime contribution: an inference
//!   coordinator that batches requests and drives a pluggable backend
//!   (compiled XLA artifacts, the simulated engine farm, or a mock).
//!   Execution cost is part of the API: `infer_batch` returns a
//!   [`coordinator::BatchReport`] whose [`coordinator::BatchCost`]
//!   carries the farm-aggregated [`arch::SimStats`] plus derived
//!   GOPS/joules, attributed per request and accumulated in the serving
//!   metrics; [`coordinator::Router`] fronts many farms behind one
//!   ingress (least-outstanding dispatch, merged metrics).
//! * [`scheduler`] — the engine-farm layer: a pool of worker threads each
//!   wrapping an [`arch::EngineSim`], a sharding planner that splits
//!   layers on the paper's `P_N`-filter group boundaries (plus a
//!   layer-pipeline mode for whole networks, in the spirit of the
//!   multi-fabric 3D-TrIM follow-up), bit-exact shard merging with
//!   farm-level stats aggregation, and the artifact-free sim serving
//!   backend (`trim serve --backend sim`, `trim farm`).
//! * [`obs`] — std-only observability substrate: a span/event tracer
//!   (monotonic timestamps, parent-linked span ids, bounded ring sink,
//!   JSON-lines export via `trim trace`) and a metrics registry of
//!   saturating counters, gauges and log₂-bucketed histograms. The
//!   serving metrics build on it, the farm exposes per-engine/injector/
//!   scratch telemetry through it, and the farm's shadow-execution
//!   canary (re-running sampled shards on a `Register`-fidelity engine)
//!   publishes bit/counter divergence through the same pipeline.
//! * [`fault`] — hardware fault injection (seeded per-engine upset
//!   plans: PE bit flips, RSRB stuck-at masks, corrupted memory reads)
//!   and the ABFT filter-checksum identity the farm verifies on *every*
//!   merged shard, powering the self-healing re-execute / quarantine /
//!   replan loop (`--chaos`).
//! * [`runtime`] — PJRT wrapper (load HLO text → compile → execute); the
//!   numeric path produced by the Python build layer (`python/compile/`).
//!   Gated behind the `pjrt` cargo feature (needs the `xla` crate); the
//!   offline default compiles a stub and serving falls back to the farm.
//! * [`report`] — renderers that regenerate every table and figure of the
//!   paper's evaluation section in the paper's own row format.
//! * [`verify`] — static invariant checker (`trim check`): proves the
//!   shard planner and the closed-form counter model consistent over the
//!   whole design space — exact output coverage, halo-read conservation,
//!   cycle-bound sanity and Tables I–II counter conservation — without
//!   running a convolution, against independently re-derived laws
//!   ([`verify::laws`]).

pub mod analytics;
pub mod arch;
pub mod coordinator;
pub mod fault;
pub mod golden;
pub mod model;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod util;
pub mod verify;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
