//! Small self-contained utilities (this crate builds offline, so the
//! usual crates.io helpers are implemented in-tree).

pub mod sync;

/// SplitMix64 PRNG — deterministic synthetic data for tests, benches and
/// property-based randomised testing.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi)` (i64 range).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    /// Uniform i32 in `[lo, hi)`.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        self.range_i64(lo as i64, hi as i64) as i32
    }

    /// A vector of i32 in `[lo, hi)`.
    pub fn vec_i32(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.range_i32(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..1000 {
            let x = a.range_i32(-5, 11);
            assert_eq!(x, b.range_i32(-5, 11));
            assert!((-5..11).contains(&x));
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
