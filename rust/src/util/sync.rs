//! Synchronization facade: std primitives by default, loom's
//! model-checked equivalents under `--cfg loom`.
//!
//! The concurrent kernel of the serving stack — the work-stealing
//! injector in `scheduler/farm.rs`, the admission depth/EWMA atomics in
//! `coordinator/admission.rs`, and the router's retry accounting —
//! imports `Mutex`/`Condvar`/atomics from here instead of `std::sync`.
//! A normal build re-exports std types (zero cost, zero behaviour
//! change); compiling with `RUSTFLAGS="--cfg loom"` swaps in
//! [loom](https://docs.rs/loom)'s permutation-exploring replacements so
//! `tests/loom_models.rs` can exhaustively check every interleaving of
//! those paths. Loom is not a Cargo dependency (this crate builds
//! offline); the CI `loom` job does `cargo add loom` before
//! setting the cfg, and nothing under `cfg(loom)` compiles without it.
//!
//! Scope: only `Mutex`, `Condvar`, `MutexGuard` and the three atomic
//! types the hot structures use. `Arc`, `mpsc` and `thread` stay std —
//! the loom models re-create those inside `loom::model` themselves.

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard};

// Loom's `lock()`/`wait()` return std's `LockResult`, so poison
// recovery is spelled identically under both cfgs.
pub use std::sync::PoisonError;

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// The serving stack treats lock poisoning as survivable everywhere: a
/// worker that panicked mid-push has already surfaced a typed error
/// through its result channel, and the protected state (job queues,
/// drain deadlines, metrics) stays consistent because every critical
/// section completes its invariant before unlocking.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn lock_unpoisoned_recovers_after_panic() {
        let m = std::sync::Arc::new(Mutex::new(0u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        // A plain `.lock().unwrap()` would panic here; the helper
        // recovers the guard and the data is intact.
        *lock_unpoisoned(&m) += 1;
        assert_eq!(*lock_unpoisoned(&m), 1);
    }
}
