//! Ablation bench: the design choices DESIGN.md calls out, quantified —
//! (a) §VI extensions (RSRB sharing / ifmap tiling / global buffer),
//! (b) the iso-PE P_N-vs-P_M trade (§IV), (c) batching policy.
#[path = "bench_harness.rs"]
mod harness;
use harness::header;
use trim_sa::analytics::design_space::evaluate;
use trim_sa::analytics::extensions::{analyze_network_ext, extended_cost, rsrb_registers, Extensions};
use trim_sa::arch::ArchConfig;
use trim_sa::model::vgg16::vgg16;

fn main() {
    let cfg = ArchConfig::paper_engine();
    let net = vgg16();

    header("Ablation A — §VI extensions on VGG-16 (accesses in M, energy-equivalent)");
    let variants: [(&str, Extensions); 5] = [
        ("baseline (paper engine)", Extensions::none()),
        ("+ RSRB sharing", Extensions { rsrb_sharing: true, ifmap_tile_width: None, global_buffer_bits: None }),
        ("+ ifmap tiling W_T=64", Extensions { rsrb_sharing: false, ifmap_tile_width: Some(64), global_buffer_bits: None }),
        ("+ global buffer 18 Mb", Extensions { rsrb_sharing: false, ifmap_tile_width: None, global_buffer_bits: Some(18_000_000) }),
        ("all (§VI)", Extensions::all()),
    ];
    println!("{:<26} {:>10} {:>9} {:>9} {:>10} {:>9} {:>9}", "variant", "RSRB regs", "off-chip", "on-chip", "total", "LUTs", "BRAM Mb");
    for (name, ext) in &variants {
        let (off, on) = analyze_network_ext(&cfg, &net, ext);
        let cost = extended_cost(&cfg, ext);
        println!(
            "{:<26} {:>10} {:>9.1} {:>9.2} {:>10.1} {:>8.1}K {:>9.2}",
            name, rsrb_registers(&cfg, ext), off, on, off + on, cost.luts / 1e3, cost.bram_mbit
        );
    }

    header("Ablation B — iso-PE parallelism split (§IV, 576 PEs)");
    for (p_n, p_m) in [(4usize, 16usize), (8, 8), (16, 4)] {
        let p = evaluate(&cfg, &net, p_n, p_m);
        println!(
            "P_N={p_n:<2} P_M={p_m:<2}: {:>7.1} GOPs/s  psum {:>6.2} Mbit  BW {:>5} bits/cycle",
            p.gops, p.psum_buffer_mbit, p.io_bandwidth_bits
        );
    }

    header("Ablation C — native vs tiled kernel efficiency (PE-slot fill)");
    for k in [3usize, 5, 7, 11] {
        let t = trim_sa::model::KernelTiling::new(k, 3);
        println!("K={k:<2}: {:>2} tiles, fill {:>5.1}%", t.num_tiles(), t.fill_ratio() * 100.0);
    }
}
