//! Bench: regenerate Table I (TrIM vs Eyeriss on VGG-16).
#[path = "bench_harness.rs"]
mod harness;
use harness::{bench, header};
use trim_sa::analytics::trim_model::analyze_network;
use trim_sa::arch::ArchConfig;
use trim_sa::model::vgg16::vgg16;
use trim_sa::report::render_table1_or_2;

fn main() {
    header("Table I — TrIM vs Eyeriss, VGG-16");
    let cfg = ArchConfig::paper_engine();
    let net = vgg16();
    print!("{}", render_table1_or_2(&cfg, &net));
    println!("{}", bench("table1_analyze", 3, 100, || analyze_network(&cfg, &net).total_gops));
}
