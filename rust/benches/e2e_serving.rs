//! Bench: end-to-end serving through the coordinator/router stack —
//! latency, throughput AND simulated cost (GOPS/joules) vs batch size and
//! farm count. Runs on the simulated engine farm, so it needs no
//! artifacts and always produces numbers; when PJRT artifacts are present
//! an extra PJRT sweep runs too (no simulated cost there).
//!
//! Emits one `JSON ` line per configuration for the CI bench-trajectory
//! artifact (same convention as farm_scaling/fidelity_speedup):
//!
//! ```text
//! JSON {"bench":"e2e_serving","farms":1,"max_batch":8,"rps":...,"sim_gops":...}
//! ```
#[path = "bench_harness.rs"]
mod harness;
use harness::header;
use std::time::{Duration, Instant};
use trim_sa::arch::ArchConfig;
use trim_sa::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, InferenceBackend, PjrtBackend, Router,
};
use trim_sa::scheduler::{ShardMode, SimBackend, SimNetSpec};

fn sim_router(farms: usize, max_batch: usize) -> anyhow::Result<Router> {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
    };
    let coordinators: Vec<Coordinator> = (0..farms)
        .map(|_| {
            Coordinator::start_with(
                move || {
                    Ok(Box::new(SimBackend::with_spec(
                        2,
                        ArchConfig::small(3, 2, 1),
                        SimNetSpec::tiny(),
                        ShardMode::FilterShards,
                    )) as Box<dyn InferenceBackend>)
                },
                cfg,
            )
        })
        .collect::<anyhow::Result<_>>()?;
    Router::new(coordinators)
}

fn main() -> anyhow::Result<()> {
    header("e2e serving — sim engine farms behind the coordinator/router");
    let n_req = 64usize;
    let mut json_lines = Vec::new();
    for (farms, max_batch) in [(1usize, 1usize), (1, 4), (1, 16), (2, 16)] {
        let router = sim_router(farms, max_batch)?;
        let len = router.input_len();
        let t0 = Instant::now();
        let pending: Vec<_> = (0..n_req)
            .map(|i| {
                let img: Vec<i32> = (0..len).map(|j| ((i * 31 + j) % 256) as i32).collect();
                router.submit(img).unwrap()
            })
            .collect();
        for mut rx in pending {
            rx.recv()?;
        }
        let wall = t0.elapsed();
        let m = router.metrics();
        let rps = n_req as f64 / wall.as_secs_f64();
        println!(
            "farms={farms} max_batch={max_batch:<3} {rps:>7.1} req/s   {:>7.2} sim GOPs/s   {:>12} sim cycles   {:>9.3} mJ   p50 {:>9.3?}   p95 {:>9.3?}   {} batches (mean {:.1})",
            m.sim_gops,
            m.sim_cycles,
            m.sim_joules * 1e3,
            m.p50_latency,
            m.p95_latency,
            m.batches,
            m.mean_batch
        );
        json_lines.push(format!(
            "JSON {{\"bench\":\"e2e_serving\",\"backend\":\"sim\",\"farms\":{farms},\
             \"max_batch\":{max_batch},\"requests\":{n_req},\"rps\":{rps:.2},\
             \"sim_gops\":{:.4},\"sim_cycles\":{},\"sim_joules\":{:.6e},\
             \"p50_us\":{},\"p95_us\":{},\"mean_batch\":{:.2}}}",
            m.sim_gops,
            m.sim_cycles,
            m.sim_joules,
            m.p50_latency.as_micros(),
            m.p95_latency.as_micros(),
            m.mean_batch
        ));
        // Full observability snapshot for the largest configuration — the
        // bench-trajectory artifact keeps one complete MetricsSnapshot
        // (queue-wait/service histograms, p99, canary totals) per run.
        if (farms, max_batch) == (2, 16) {
            json_lines.push(format!(
                "JSON {{\"bench\":\"e2e_serving\",\"kind\":\"snapshot\",\"farms\":{farms},\
                 \"max_batch\":{max_batch},\"metrics\":{}}}",
                m.render_json()
            ));
        }
    }

    // Optional PJRT sweep (the original e2e path) — skipped without
    // artifacts or with PJRT support compiled out.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        'pjrt: for max_batch in [1usize, 16] {
            let cfg = CoordinatorConfig {
                batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
            };
            let d = dir.clone();
            let c = match Coordinator::start_with(
                move || Ok(Box::new(PjrtBackend::load(&d)?) as _),
                cfg,
            ) {
                Ok(c) => c,
                Err(e) => {
                    println!("SKIP pjrt: backend unavailable ({e:#}) — build with --features pjrt");
                    break 'pjrt;
                }
            };
            let len = c.input_len();
            let t0 = Instant::now();
            let rxs: Vec<_> = (0..n_req)
                .map(|i| c.submit((0..len).map(|j| ((i * 31 + j) % 256) as i32).collect()).unwrap())
                .collect();
            for rx in rxs {
                rx.recv()?;
            }
            let rps = n_req as f64 / t0.elapsed().as_secs_f64();
            let m = c.metrics();
            println!(
                "pjrt max_batch={max_batch:<3} {rps:>7.1} req/s   p50 {:>9.3?}   p95 {:>9.3?}   {} batches (mean {:.1})",
                m.p50_latency,
                m.p95_latency,
                m.batches,
                m.mean_batch
            );
            json_lines.push(format!(
                "JSON {{\"bench\":\"e2e_serving\",\"backend\":\"pjrt\",\"farms\":1,\
                 \"max_batch\":{max_batch},\"requests\":{n_req},\"rps\":{rps:.2},\"sim_gops\":0}}"
            ));
        }
    } else {
        println!("note: artifacts/ missing — PJRT sweep skipped (sim sweep above is the gate)");
    }

    for line in &json_lines {
        println!("{line}");
    }
    Ok(())
}
