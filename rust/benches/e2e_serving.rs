//! Bench: end-to-end serving through the coordinator/router stack —
//! latency, throughput AND simulated cost (GOPS/joules) vs batch size and
//! farm count. Runs on the simulated engine farm, so it needs no
//! artifacts and always produces numbers; when PJRT artifacts are present
//! an extra PJRT sweep runs too (no simulated cost there).
//!
//! Emits one `JSON ` line per configuration for the CI bench-trajectory
//! artifact (same convention as farm_scaling/fidelity_speedup):
//!
//! ```text
//! JSON {"bench":"e2e_serving","farms":1,"max_batch":8,"rps":...,"sim_gops":...}
//! ```
//!
//! The overload sweep floods a bounded-ingress router past its admission
//! budget (offered load × queue cap) and emits
//! `{"kind":"overload",...,"shed_rate":...,"p99_us":...}` rows — the
//! robustness trajectory: shed rate should rise as the cap tightens while
//! the served tail latency stays bounded.
//! The chaos sweep re-runs the same serving stack under seeded hardware
//! fault injection (`{"kind":"chaos",...}` rows): fault rate × ABFT
//! detection coverage × goodput. Its zero-rate row is shape-identical to
//! the plain `farms=1,max_batch=16` row, so diffing their `rps` bounds
//! the always-on checksum cost of the disabled-injection path.
//! The straggler sweep (`{"kind":"straggler",...}` rows) runs the
//! CL1-class workload under seeded *timing* chaos — `slow` delays a
//! fraction of (engine, shard) executions 2–8 ms, `hang` parks them —
//! with hedged re-execution on and off at each rate. Every served
//! response is checked bit-exact against the golden model, and the hang
//! pair asserts the gray-failure headline: hedged p99 strictly below the
//! unhedged counterfactual (which must ride the analytic valve + retry).
#[path = "bench_harness.rs"]
mod harness;
use harness::header;
use std::time::{Duration, Instant};
use trim_sa::arch::{ArchConfig, ExecFidelity};
use trim_sa::coordinator::{
    AdmissionConfig, BatcherConfig, Coordinator, CoordinatorConfig, FaultConfig, FaultModel,
    InferenceBackend, PjrtBackend, Router, ServeError,
};
use trim_sa::scheduler::{CanaryConfig, FarmConfig, ShardMode, SimBackend, SimNetSpec};

fn sim_backend() -> Box<dyn InferenceBackend> {
    Box::new(SimBackend::with_spec(
        2,
        ArchConfig::small(3, 2, 1),
        SimNetSpec::tiny(),
        ShardMode::FilterShards,
    ))
}

fn sim_router(farms: usize, max_batch: usize) -> anyhow::Result<Router> {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
        ..Default::default()
    };
    let coordinators: Vec<Coordinator> = (0..farms)
        .map(|_| Coordinator::start_with(|| Ok(sim_backend()), cfg))
        .collect::<anyhow::Result<_>>()?;
    Router::new(coordinators)
}

/// Flood one bounded-ingress farm with `offered` back-to-back submits and
/// report what admission shed, what resolved, and the served-tail p99.
fn overload_config(
    queue_cap: usize,
    offered: usize,
    json_lines: &mut Vec<String>,
) -> anyhow::Result<()> {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        admission: AdmissionConfig { queue_cap, budget_cycles: None, client_rps: None },
    };
    let c = Coordinator::start_with(|| Ok(sim_backend()), cfg)?;
    let router = Router::new(vec![c])?;
    let len = router.input_len();
    let t0 = Instant::now();
    let mut shed_at_submit = 0usize;
    let mut pending = Vec::new();
    for i in 0..offered {
        let img: Vec<i32> = (0..len).map(|j| ((i * 31 + j) % 256) as i32).collect();
        match router.submit(img) {
            Ok(r) => pending.push(r),
            Err(e) if e.downcast_ref::<ServeError>().is_some() => shed_at_submit += 1,
            Err(e) => return Err(e),
        }
    }
    let mut served = 0usize;
    let mut failed = 0usize;
    for mut r in pending {
        match r.recv() {
            Ok(_) => served += 1,
            Err(e) if e.downcast_ref::<ServeError>().is_some() => failed += 1,
            Err(e) => return Err(e),
        }
    }
    let wall = t0.elapsed();
    let m = router.drain(Duration::from_secs(5));
    let shed_rate = m.shed as f64 / offered as f64;
    println!(
        "overload queue_cap={queue_cap:<4} offered={offered:<4} shed {:>4} ({shed_rate:>5.1}% of offered)  served {served}  failed {failed}  p99 {:>9.3?}  wall {wall:>9.3?}",
        m.shed,
        m.p99_latency,
        shed_rate = shed_rate * 100.0
    );
    json_lines.push(format!(
        "JSON {{\"bench\":\"e2e_serving\",\"kind\":\"overload\",\"queue_cap\":{queue_cap},\
         \"offered\":{offered},\"shed\":{},\"shed_at_submit\":{shed_at_submit},\
         \"served\":{served},\"failed\":{failed},\"shed_rate\":{shed_rate:.4},\
         \"p99_us\":{},\"queue_wait_p99_us_est\":{}}}",
        m.shed,
        m.p99_latency.as_micros(),
        m.queue_wait.quantile(0.99)
    ));
    Ok(())
}

/// One chaos-sweep point: the `sim_backend()` shape under seeded fault
/// injection at `rate`. Detected faults re-execute (bit-exact); the rare
/// shard whose draw fires on every engine exhausts its retries into a
/// typed failure — counted, never a wrong answer. `rate == 0` is the
/// disabled-injection path on the always-on ABFT checksums.
fn chaos_config(rate: f64, json_lines: &mut Vec<String>) -> anyhow::Result<()> {
    let chaos = FaultConfig::new(rate, 0xFA17_5EED, FaultModel::Pe);
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(2) },
        ..Default::default()
    };
    let c = Coordinator::start_with(
        move || {
            Ok(Box::new(SimBackend::with_chaos(
                2,
                ArchConfig::small(3, 2, 1),
                SimNetSpec::tiny(),
                ShardMode::FilterShards,
                ExecFidelity::Fast,
                CanaryConfig::default(),
                chaos,
            )) as Box<dyn InferenceBackend>)
        },
        cfg,
    )?;
    let router = Router::new(vec![c])?;
    let len = router.input_len();
    let n_req = 48usize;
    let t0 = Instant::now();
    let pending: Vec<_> = (0..n_req)
        .map(|i| {
            let img: Vec<i32> = (0..len).map(|j| ((i * 31 + j) % 256) as i32).collect();
            router.submit(img)
        })
        .collect::<anyhow::Result<_>>()?;
    let mut served = 0usize;
    let mut failed = 0usize;
    for mut r in pending {
        match r.recv() {
            Ok(_) => served += 1,
            Err(e) if e.downcast_ref::<ServeError>().is_some() => failed += 1,
            Err(e) => return Err(e),
        }
    }
    let wall = t0.elapsed();
    let m = router.drain(Duration::from_secs(5));
    let rps = served as f64 / wall.as_secs_f64();
    let f = m.fault;
    let detection = if f.injected > 0 { f.detected as f64 / f.injected as f64 } else { 1.0 };
    println!(
        "chaos rate={rate:<5} {rps:>7.1} req/s   served {served:>3}  failed {failed:>2}   injected {:>3}  detected {:>3}  corrected {:>3}  reexecuted {:>3}  quarantined {:>2}   p95 {:>9.3?}",
        f.injected, f.detected, f.corrected, f.reexecuted, f.quarantined, m.p95_latency
    );
    json_lines.push(format!(
        "JSON {{\"bench\":\"e2e_serving\",\"kind\":\"chaos\",\"rate\":{rate},\
         \"requests\":{n_req},\"served\":{served},\"failed\":{failed},\"rps\":{rps:.2},\
         \"injected\":{},\"detected\":{},\"corrected\":{},\"reexecuted\":{},\
         \"quarantined\":{},\"detection_rate\":{detection:.4},\
         \"p50_us\":{},\"p95_us\":{}}}",
        f.injected,
        f.detected,
        f.corrected,
        f.reexecuted,
        f.quarantined,
        m.p50_latency.as_micros(),
        m.p95_latency.as_micros()
    ));
    Ok(())
}

/// One straggler-sweep point: the CL1-class workload under seeded timing
/// chaos, with hedging on (`hedge_factor = 4`) or off (`0`). Unhedged
/// runs carry a 150 ms valve floor so a hung layer resolves through the
/// typed analytic valve and the router's in-place retry rather than the
/// 300 s cold-farm default. Returns `(p99_us, rps)` for the hang-pair
/// comparison in `main`.
fn straggler_config(
    model: FaultModel,
    rate: f64,
    hedge: bool,
    reference: &SimBackend,
    json_lines: &mut Vec<String>,
) -> anyhow::Result<(u128, f64)> {
    let chaos = if rate > 0.0 {
        FaultConfig::new(rate, 0x57A6_617E, model)
    } else {
        FaultConfig::disabled()
    };
    let hedge_factor = if hedge { 4.0 } else { 0.0 };
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        ..Default::default()
    };
    let c = Coordinator::start_with(
        move || {
            let farm =
                FarmConfig::with_fidelity(4, ArchConfig::small(3, 2, 1), ExecFidelity::Fast)
                    .with_chaos(chaos)
                    .with_hedge(hedge_factor, 3)
                    .with_valve(Duration::from_millis(150), 8.0);
            Ok(Box::new(SimBackend::with_farm_config(
                farm,
                SimNetSpec::cl1_class(),
                ShardMode::Auto,
            )) as Box<dyn InferenceBackend>)
        },
        cfg,
    )?;
    let router = Router::new(vec![c])?;
    let len = router.input_len();
    let n_req = 24usize;
    let images: Vec<Vec<i32>> = (0..n_req)
        .map(|i| (0..len).map(|j| ((i * 31 + j) % 256) as i32).collect())
        .collect();
    let t0 = Instant::now();
    let pending: Vec<_> =
        images.iter().map(|img| router.submit(img.clone())).collect::<anyhow::Result<_>>()?;
    let mut served = 0usize;
    let mut failed = 0usize;
    for (img, mut r) in images.iter().zip(pending) {
        match r.recv() {
            Ok(resp) => {
                anyhow::ensure!(
                    resp.logits == reference.reference_logits(img),
                    "served logits diverged from golden under {model} chaos (rate {rate})"
                );
                served += 1;
            }
            Err(e) if e.downcast_ref::<ServeError>().is_some() => failed += 1,
            Err(e) => return Err(e),
        }
    }
    let wall = t0.elapsed();
    let m = router.drain(Duration::from_secs(10));
    let rps = served as f64 / wall.as_secs_f64();
    let f = m.fault;
    let p99_us = m.p99_latency.as_micros();
    println!(
        "straggler model={model:<4} rate={rate:<5} hedged={hedged:<5} {rps:>7.1} req/s   served {served:>3}  failed {failed:>2}   stragglers {:>3}  hedged {:>3}  won {:>3}  wasted {:>3}  timing-quarantined {:>2}   p99 {:>9.3?}",
        f.stragglers_detected,
        f.hedged,
        f.hedge_won,
        f.hedge_wasted,
        f.timing_quarantined,
        m.p99_latency,
        hedged = hedge
    );
    json_lines.push(format!(
        "JSON {{\"bench\":\"e2e_serving\",\"kind\":\"straggler\",\"model\":\"{model}\",\
         \"rate\":{rate},\"hedged\":{hedge},\"requests\":{n_req},\"served\":{served},\
         \"failed\":{failed},\"rps\":{rps:.2},\"stragglers\":{},\"hedged_count\":{},\
         \"hedge_won\":{},\"hedge_wasted\":{},\"timing_quarantined\":{},\
         \"p50_us\":{},\"p99_us\":{p99_us}}}",
        f.stragglers_detected,
        f.hedged,
        f.hedge_won,
        f.hedge_wasted,
        f.timing_quarantined,
        m.p50_latency.as_micros(),
    ));
    Ok((p99_us, rps))
}

fn main() -> anyhow::Result<()> {
    header("e2e serving — sim engine farms behind the coordinator/router");
    let n_req = 64usize;
    let mut json_lines = Vec::new();
    for (farms, max_batch) in [(1usize, 1usize), (1, 4), (1, 16), (2, 16)] {
        let router = sim_router(farms, max_batch)?;
        let len = router.input_len();
        let t0 = Instant::now();
        let pending: Vec<_> = (0..n_req)
            .map(|i| {
                let img: Vec<i32> = (0..len).map(|j| ((i * 31 + j) % 256) as i32).collect();
                router.submit(img).unwrap()
            })
            .collect();
        for mut rx in pending {
            rx.recv()?;
        }
        let wall = t0.elapsed();
        let m = router.metrics();
        let rps = n_req as f64 / wall.as_secs_f64();
        println!(
            "farms={farms} max_batch={max_batch:<3} {rps:>7.1} req/s   {:>7.2} sim GOPs/s   {:>12} sim cycles   {:>9.3} mJ   p50 {:>9.3?}   p95 {:>9.3?}   {} batches (mean {:.1})",
            m.sim_gops,
            m.sim_cycles,
            m.sim_joules * 1e3,
            m.p50_latency,
            m.p95_latency,
            m.batches,
            m.mean_batch
        );
        json_lines.push(format!(
            "JSON {{\"bench\":\"e2e_serving\",\"backend\":\"sim\",\"farms\":{farms},\
             \"max_batch\":{max_batch},\"requests\":{n_req},\"rps\":{rps:.2},\
             \"sim_gops\":{:.4},\"sim_cycles\":{},\"sim_joules\":{:.6e},\
             \"p50_us\":{},\"p95_us\":{},\"mean_batch\":{:.2}}}",
            m.sim_gops,
            m.sim_cycles,
            m.sim_joules,
            m.p50_latency.as_micros(),
            m.p95_latency.as_micros(),
            m.mean_batch
        ));
        // Full observability snapshot for the largest configuration — the
        // bench-trajectory artifact keeps one complete MetricsSnapshot
        // (queue-wait/service histograms, p99, canary totals) per run.
        if (farms, max_batch) == (2, 16) {
            json_lines.push(format!(
                "JSON {{\"bench\":\"e2e_serving\",\"kind\":\"snapshot\",\"farms\":{farms},\
                 \"max_batch\":{max_batch},\"metrics\":{}}}",
                m.render_json()
            ));
        }
    }

    // Overload sweep: offered load × admission budget. Tight caps must
    // shed (nonzero shed_rate) while the served tail stays bounded.
    for (queue_cap, offered) in [(4usize, 96usize), (16, 96), (64, 96)] {
        overload_config(queue_cap, offered, &mut json_lines)?;
    }

    // Chaos sweep: seeded hardware fault injection at rising rates. The
    // zero-rate row bounds the disabled-injection ABFT cost against the
    // plain farms=1,max_batch=16 row above; the nonzero rows trace
    // detection coverage (should stay 1.0) and goodput under self-healing.
    for rate in [0.0, 0.02, 0.1] {
        chaos_config(rate, &mut json_lines)?;
    }

    // Straggler sweep: gray failures. Slow chaos at rising rates with
    // hedging off/on traces how much tail the hedges claw back; the hang
    // pair is the acceptance gate — hedged p99 must beat the unhedged
    // counterfactual, which pays the analytic valve + retry per hang.
    let reference = SimBackend::with_spec(
        1,
        ArchConfig::small(3, 2, 1),
        SimNetSpec::cl1_class(),
        ShardMode::Auto,
    );
    for rate in [0.0, 0.05, 0.2] {
        for hedge in [false, true] {
            straggler_config(FaultModel::Slow, rate, hedge, &reference, &mut json_lines)?;
        }
    }
    let (p99_unhedged, _) =
        straggler_config(FaultModel::Hang, 0.05, false, &reference, &mut json_lines)?;
    let (p99_hedged, _) =
        straggler_config(FaultModel::Hang, 0.05, true, &reference, &mut json_lines)?;
    anyhow::ensure!(
        p99_hedged < p99_unhedged,
        "hedged p99 ({p99_hedged} µs) must be strictly below the unhedged hang \
         counterfactual ({p99_unhedged} µs)"
    );
    println!(
        "hang 0.05: hedged p99 {p99_hedged} µs vs unhedged {p99_unhedged} µs — hedging bounds the tail"
    );

    // Optional PJRT sweep (the original e2e path) — skipped without
    // artifacts or with PJRT support compiled out.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        'pjrt: for max_batch in [1usize, 16] {
            let cfg = CoordinatorConfig {
                batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
                ..Default::default()
            };
            let d = dir.clone();
            let c = match Coordinator::start_with(
                move || Ok(Box::new(PjrtBackend::load(&d)?) as _),
                cfg,
            ) {
                Ok(c) => c,
                Err(e) => {
                    println!("SKIP pjrt: backend unavailable ({e:#}) — build with --features pjrt");
                    break 'pjrt;
                }
            };
            let len = c.input_len();
            let t0 = Instant::now();
            let rxs: Vec<_> = (0..n_req)
                .map(|i| c.submit((0..len).map(|j| ((i * 31 + j) % 256) as i32).collect()).unwrap())
                .collect();
            for rx in rxs {
                rx.recv()??;
            }
            let rps = n_req as f64 / t0.elapsed().as_secs_f64();
            let m = c.metrics();
            println!(
                "pjrt max_batch={max_batch:<3} {rps:>7.1} req/s   p50 {:>9.3?}   p95 {:>9.3?}   {} batches (mean {:.1})",
                m.p50_latency,
                m.p95_latency,
                m.batches,
                m.mean_batch
            );
            json_lines.push(format!(
                "JSON {{\"bench\":\"e2e_serving\",\"backend\":\"pjrt\",\"farms\":1,\
                 \"max_batch\":{max_batch},\"requests\":{n_req},\"rps\":{rps:.2},\"sim_gops\":0}}"
            ));
        }
    } else {
        println!("note: artifacts/ missing — PJRT sweep skipped (sim sweep above is the gate)");
    }

    for line in &json_lines {
        println!("{line}");
    }
    Ok(())
}
