//! Bench: end-to-end serving over the PJRT artifacts (latency/throughput
//! vs batch size). Skips gracefully when artifacts/ is missing.
#[path = "bench_harness.rs"]
mod harness;
use harness::header;
use std::time::{Duration, Instant};
use trim_sa::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, PjrtBackend};

fn main() -> anyhow::Result<()> {
    header("e2e serving — TrimNet over PJRT artifacts");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("SKIP: artifacts/ missing — run `make artifacts`");
        return Ok(());
    }
    let n_req = 64;
    for max_batch in [1usize, 4, 16] {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
        };
        let d = dir.clone();
        // Graceful skip when artifacts exist but PJRT support is compiled
        // out (the offline default — see Cargo.toml's `pjrt` feature).
        let c = match Coordinator::start_with(move || Ok(Box::new(PjrtBackend::load(&d)?) as _), cfg) {
            Ok(c) => c,
            Err(e) => {
                println!("SKIP: PJRT backend unavailable ({e:#}) — build with --features pjrt");
                return Ok(());
            }
        };
        let len = c.input_len();
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n_req)
            .map(|i| c.submit((0..len).map(|j| ((i * 31 + j) % 256) as i32).collect()).unwrap())
            .collect();
        for rx in rxs {
            rx.recv()?;
        }
        let wall = t0.elapsed();
        let m = c.metrics();
        println!(
            "max_batch={max_batch:<3} {:>7.1} req/s   p50 {:>9.3?}   p95 {:>9.3?}   {} batches (mean {:.1})",
            n_req as f64 / wall.as_secs_f64(),
            m.p50_latency,
            m.p95_latency,
            m.batches,
            m.mean_batch
        );
    }
    Ok(())
}
