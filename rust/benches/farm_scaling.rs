//! Bench: serving throughput vs engine-farm size — requests/sec at 1, 2,
//! 4, 8 simulated TrIM engines, in both sharding modes and both execution
//! fidelities, through the full coordinator (ingress → batcher → sim
//! backend). Needs no artifacts.
//!
//! The fidelity axis is the PR-over-PR trajectory hook: `register` is the
//! farm's pre-fast-tier behaviour (every engine cycle-accurate), `fast` is
//! the current default — same logits, closed-form counters. The rps ratio
//! between the two at equal engine count is the serving-level speedup of
//! the fast tier.
//!
//! Emits one JSON line per configuration (prefixed `JSON `) so the bench
//! trajectory can be scraped into EXPERIMENTS.md / dashboards:
//!
//! ```text
//! JSON {"bench":"farm_scaling","mode":"FilterShards","fidelity":"fast",...}
//! ```

#[path = "bench_harness.rs"]
mod harness;
use harness::header;
use std::time::{Duration, Instant};
use trim_sa::arch::{ArchConfig, ExecFidelity};
use trim_sa::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, InferenceBackend};
use trim_sa::scheduler::{ShardMode, SimBackend, SimNetSpec};

fn main() -> anyhow::Result<()> {
    header("farm scaling — serving throughput vs engine count (sim backend)");
    let n_req = 96usize; // the acceptance-sized workload
    let max_batch = 8usize;
    let mut json_lines = Vec::new();
    for fidelity in [ExecFidelity::Register, ExecFidelity::Fast] {
        for mode in [ShardMode::FilterShards, ShardMode::LayerPipeline] {
            let mut base_rps = 0.0f64;
            for engines in [1usize, 2, 4, 8] {
                let cfg = CoordinatorConfig {
                    batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
                };
                let c = Coordinator::start_with(
                    move || {
                        Ok(Box::new(SimBackend::with_fidelity(
                            engines,
                            ArchConfig::small(3, 2, 1),
                            SimNetSpec::tiny(),
                            mode,
                            fidelity,
                        )) as Box<dyn InferenceBackend>)
                    },
                    cfg,
                )?;
                let len = c.input_len();
                let t0 = Instant::now();
                let pending: Vec<_> = (0..n_req)
                    .map(|i| {
                        let img: Vec<i32> =
                            (0..len).map(|j| ((i * 131 + j * 31) % 256) as i32).collect();
                        c.submit(img).unwrap()
                    })
                    .collect();
                for rx in pending {
                    rx.recv()?;
                }
                let wall = t0.elapsed();
                let m = c.metrics();
                let rps = n_req as f64 / wall.as_secs_f64();
                if engines == 1 {
                    base_rps = rps;
                }
                println!(
                    "{fidelity:<8} {mode:?} engines={engines:<2} {rps:>9.1} req/s ({:>5.2}x vs 1 engine)  p50 {:>9.3?}  p95 {:>9.3?}  {} batches (mean {:.1})",
                    rps / base_rps,
                    m.p50_latency,
                    m.p95_latency,
                    m.batches,
                    m.mean_batch
                );
                json_lines.push(format!(
                    "JSON {{\"bench\":\"farm_scaling\",\"mode\":\"{mode:?}\",\"fidelity\":\"{fidelity}\",\
                     \"engines\":{engines},\"requests\":{n_req},\"max_batch\":{max_batch},\"rps\":{rps:.2},\
                     \"speedup_vs_1\":{:.3},\"p50_us\":{},\"p95_us\":{},\"mean_batch\":{:.2}}}",
                    rps / base_rps,
                    m.p50_latency.as_micros(),
                    m.p95_latency.as_micros(),
                    m.mean_batch
                ));
            }
        }
    }
    for line in &json_lines {
        println!("{line}");
    }
    Ok(())
}
