//! Bench: serving throughput vs engine-farm size — requests/sec through
//! the full coordinator (ingress → batcher → sim backend), in both
//! execution fidelities, across the farm's shard modes. Needs no
//! artifacts.
//!
//! Two workloads:
//!
//! * `tiny` — the `SimNetSpec::tiny` serving CNN at 1/2/4/8 engines in
//!   {filter, pipeline} mode: the PR-over-PR trajectory rows carried since
//!   PR 1 (the fidelity axis since PR 2).
//! * `cl1` — the `SimNetSpec::cl1_class` workload (one wide-spatial,
//!   filter-starved 3→10 layer over 120², the VGG-16 CL1 geometry class)
//!   at 4/8/16 engines in {filter, spatial, hybrid, auto} mode: the
//!   shard-axis sweep. On 8 narrow engines the filter axis is bounded at
//!   5× while rows bound 8× — `auto` must match or beat `filter` rps at
//!   8 engines (strictly, on the fast tier). At 16 engines *both* single
//!   axes fall short (filters 10×, rows 15×) and auto resolves to the
//!   2×8 hybrid grid (bound 16×) — its rps must be ≥ the spatial-only
//!   row at the same engine count.
//!
//! Emits one JSON line per configuration (prefixed `JSON `) so the bench
//! trajectory can be scraped into EXPERIMENTS.md / dashboards:
//!
//! ```text
//! JSON {"bench":"farm_scaling","workload":"cl1","shard_mode":"auto",...}
//! ```

#[path = "bench_harness.rs"]
mod harness;
use harness::header;
use std::time::{Duration, Instant};
use trim_sa::arch::{ArchConfig, ExecFidelity};
use trim_sa::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, InferenceBackend};
use trim_sa::scheduler::{ShardMode, SimBackend, SimNetSpec};

#[allow(clippy::too_many_arguments)]
fn run_config(
    workload: &str,
    spec: &SimNetSpec,
    mode: ShardMode,
    fidelity: ExecFidelity,
    engines: usize,
    n_req: usize,
    max_batch: usize,
    base_rps: &mut f64,
    json_lines: &mut Vec<String>,
) -> anyhow::Result<()> {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
        ..Default::default()
    };
    let spec = spec.clone();
    let c = Coordinator::start_with(
        move || {
            Ok(Box::new(SimBackend::with_fidelity(
                engines,
                ArchConfig::small(3, 2, 1),
                spec,
                mode,
                fidelity,
            )) as Box<dyn InferenceBackend>)
        },
        cfg,
    )?;
    let len = c.input_len();
    let t0 = Instant::now();
    let pending: Vec<_> = (0..n_req)
        .map(|i| {
            let img: Vec<i32> = (0..len).map(|j| ((i * 131 + j * 31) % 256) as i32).collect();
            c.submit(img).unwrap()
        })
        .collect();
    for rx in pending {
        rx.recv()??;
    }
    let wall = t0.elapsed();
    let m = c.metrics();
    let rps = n_req as f64 / wall.as_secs_f64();
    if *base_rps == 0.0 {
        *base_rps = rps;
    }
    println!(
        "{workload:<4} {fidelity:<8} {mode:<8} engines={engines:<2} {rps:>9.1} req/s ({:>5.2}x vs base)  p50 {:>9.3?}  p95 {:>9.3?}  {} batches (mean {:.1})",
        rps / *base_rps,
        m.p50_latency,
        m.p95_latency,
        m.batches,
        m.mean_batch
    );
    json_lines.push(format!(
        "JSON {{\"bench\":\"farm_scaling\",\"workload\":\"{workload}\",\"shard_mode\":\"{mode}\",\
         \"fidelity\":\"{fidelity}\",\"engines\":{engines},\"requests\":{n_req},\
         \"max_batch\":{max_batch},\"rps\":{rps:.2},\"speedup_vs_base\":{:.3},\
         \"p50_us\":{},\"p95_us\":{},\"mean_batch\":{:.2}}}",
        rps / *base_rps,
        m.p50_latency.as_micros(),
        m.p95_latency.as_micros(),
        m.mean_batch
    ));
    Ok(())
}

fn main() -> anyhow::Result<()> {
    header("farm scaling — serving throughput vs engine count and shard mode (sim backend)");
    let n_req = 96usize; // the acceptance-sized workload
    let max_batch = 8usize;
    let mut json_lines = Vec::new();
    let tiny = SimNetSpec::tiny();
    let cl1 = SimNetSpec::cl1_class();
    for fidelity in [ExecFidelity::Register, ExecFidelity::Fast] {
        // Trajectory rows carried since PR 1: the tiny serving net across
        // engine counts, filter-sharded and layer-pipelined. Base rps for
        // the speedup column is the 1-engine run of each (mode, fidelity).
        for mode in [ShardMode::FilterShards, ShardMode::LayerPipeline] {
            let mut base = 0.0f64;
            for engines in [1usize, 2, 4, 8] {
                run_config("tiny", &tiny, mode, fidelity, engines, n_req, max_batch, &mut base, &mut json_lines)?;
            }
        }
        // The shard-axis sweep on the CL1-class layer: filter sharding is
        // starved (10 filter groups on these P_N = 1 engines — the largest
        // shard still carries 2 groups at 8 engines, bounding 5×) while
        // spatial/auto split 120 output rows evenly at 8 engines (8×); at
        // 16 engines rows cap at 15× and only the hybrid 2×8 grid (which
        // auto resolves to) reaches 16×. Base rps is the 4-engine filter
        // run of each fidelity. 32 requests: the layer is ~50× the tiny
        // net's work per image, so the smaller workload keeps the
        // register rows affordable without losing the signal.
        let cl1_req = 32usize;
        let mut base = 0.0f64;
        for mode in
            [ShardMode::FilterShards, ShardMode::Spatial, ShardMode::Hybrid, ShardMode::Auto]
        {
            for engines in [4usize, 8, 16] {
                run_config("cl1", &cl1, mode, fidelity, engines, cl1_req, max_batch, &mut base, &mut json_lines)?;
            }
        }
    }
    for line in &json_lines {
        println!("{line}");
    }
    Ok(())
}
