//! Bench: regenerate Fig. 1 (VGG-16 per-CL memory + ops profile).
#[path = "bench_harness.rs"]
mod harness;
use harness::{bench, header};
use trim_sa::model::vgg16::vgg16;
use trim_sa::report::render_fig1;

fn main() {
    header("Fig. 1 — VGG-16 memory/ops profile");
    let net = vgg16();
    print!("{}", render_fig1(&net, 8));
    println!("{}", bench("fig1_render", 3, 50, || render_fig1(&net, 8).len()));
}
