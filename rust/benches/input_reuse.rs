//! Bench: the §II input-reuse claim, measured by the register-accurate
//! slice simulator at full 224×224 scale, plus the WS-GeMM ablation.
#[path = "bench_harness.rs"]
mod harness;
use harness::{bench, header};
use trim_sa::analytics::ws_gemm::{model_layer, WsGemmConfig};
use trim_sa::arch::SliceSim;
use trim_sa::model::ConvLayer;

fn main() {
    header("Input reuse — TrIM slice vs WS-GeMM (per weight-resident pass)");
    let hw = 224;
    let ifmap: Vec<i32> = (0..hw * hw).map(|i| i as i32 % 256).collect();
    let weights = vec![1i32, -2, 3, -4, 5, -6, 7, -8, 9];
    let mut slice = SliceSim::new(3, 226);
    let r = slice.run_conv(&ifmap, hw, hw, &weights, 1, 1);
    let trim_reads = r.stats.ext_input_reads as f64;
    let layer = ConvLayer::new("cl", 224, 3, 1, 1, 1, 1);
    let ws = model_layer(&WsGemmConfig::default(), &layer, 1);
    let ws_reads = (layer.h_o() * layer.w_o() * 9) as f64;
    println!("TrIM slice ifmap reads : {:>10.0} ({:+.2}% overhead)", trim_reads, (trim_reads / (hw * hw) as f64 - 1.0) * 100.0);
    println!("WS-GeMM im2col reads   : {:>10.0} (redundancy {:.1}x)", ws_reads, ws.redundancy);
    println!("TrIM saving            : {:>10.1}x", ws_reads / trim_reads);
    println!("{}", bench("slice_224x224_full_pass", 1, 5, || {
        SliceSim::new(3, 226).run_conv(&ifmap, hw, hw, &weights, 1, 1).stats.cycles
    }));
}
