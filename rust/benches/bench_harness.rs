//! Minimal benchmark harness shared by all bench targets (the crate
//! builds offline, so no criterion; this reproduces its essentials:
//! warmup, repeated timed runs, mean/min/max/stddev reporting).
//!
//! Each bench target regenerates one of the paper's tables/figures and
//! reports how long the regeneration takes, so `cargo bench` both
//! reproduces the evaluation section and tracks the performance of the
//! models/simulators themselves.

#![allow(dead_code)]

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12?} mean  {:>12?} min  {:>12?} max  ±{:>10?}  ({} iters)",
            self.name, self.mean, self.min, self.max, self.stddev, self.iters
        )
    }
}

/// Time `f` with `warmup` + `iters` runs; a `black_box`-style sink keeps
/// results alive.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    let sum: Duration = times.iter().sum();
    let mean = sum / iters as u32;
    let min = *times.iter().min().unwrap();
    let max = *times.iter().max().unwrap();
    let var = times
        .iter()
        .map(|t| {
            let d = t.as_secs_f64() - mean.as_secs_f64();
            d * d
        })
        .sum::<f64>()
        / iters as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        min,
        max,
        stddev: Duration::from_secs_f64(var.sqrt()),
    }
}

/// Print a bench header.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}
