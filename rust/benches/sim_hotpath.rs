//! Bench: the simulator hot paths — the cycle-accurate register tier
//! (PE-array cycle updates per second across slice geometries and a small
//! engine layer, the §Perf L3 target) AND the fast-tier conv microkernel
//! (`arch/fastsim.rs::conv_rows_from_padded` — the serving hot path: the
//! K-specialized, autovectorized blocked conv), so the before/after of
//! microkernel work is recorded per PR.
//!
//! Emits one JSON line per case (prefixed `JSON `) for the CI
//! bench-trajectory artifact:
//!
//! ```text
//! JSON {"bench":"sim_hotpath","kernel":"conv_k3_cl1class","mean_ms":...,
//!       "gmacs_per_s":...}
//! ```

#[path = "bench_harness.rs"]
mod harness;
use harness::{bench, header};
use trim_sa::arch::{ArchConfig, EngineSim, SliceSim};
use trim_sa::golden::Tensor3;
use trim_sa::model::ConvLayer;
use trim_sa::util::SplitMix64;

fn main() {
    header("Simulator hot path");
    let mut rng = SplitMix64::new(1);
    let mut json = Vec::new();

    // --- register tier: the slice sweep ---
    for (hw, k) in [(56usize, 3usize), (112, 3), (224, 3), (64, 5)] {
        let ifmap = rng.vec_i32(hw * hw, 0, 256);
        let weights = rng.vec_i32(k * k, -8, 8);
        let r = bench(&format!("slice_{hw}x{hw}_k{k}"), 1, 5, || {
            SliceSim::new(k, hw + 2).run_conv(&ifmap, hw, hw, &weights, 1, 1).stats.cycles
        });
        let cycles = SliceSim::new(k, hw + 2).run_conv(&ifmap, hw, hw, &weights, 1, 1).stats.cycles;
        let rate = cycles as f64 / r.mean.as_secs_f64() / 1e6;
        println!("{r}");
        println!("{:<44} {:>10.1} Mcycles/s  ({:.0} M PE-updates/s)", " ", rate, rate * (k * k) as f64);
        json.push(format!(
            "JSON {{\"bench\":\"sim_hotpath\",\"kernel\":\"slice_{hw}x{hw}_k{k}\",\
             \"mean_ms\":{:.3},\"mcycles_per_s\":{rate:.1}}}",
            r.mean.as_secs_f64() * 1e3,
        ));
    }

    // --- register tier: a small engine layer ---
    let layer = ConvLayer::new("e", 28, 3, 8, 8, 1, 1);
    let input = Tensor3::from_fn(8, 28, 28, |c, y, x| ((c + y + x) % 251) as i32);
    let weights = rng.vec_i32(8 * 8 * 9, -8, 8);
    let sim = EngineSim::new(ArchConfig::small(3, 4, 4));
    let r = bench("engine_28x28_m8_n8", 1, 3, || sim.run_layer(&layer, &input, &weights).stats.cycles);
    println!("{r}");
    json.push(format!(
        "JSON {{\"bench\":\"sim_hotpath\",\"kernel\":\"engine_28x28_m8_n8\",\"mean_ms\":{:.3}}}",
        r.mean.as_secs_f64() * 1e3,
    ));

    // --- fast tier: the conv microkernel (serving hot path) ---
    // One case per dispatch arm: the fused K=3 kernel on the CL1-class
    // serving geometry, the same kernel on a channel-heavy deep layer,
    // the generic unit-stride K=5 arm, and the strided gather arm.
    let cases: Vec<(&str, ConvLayer)> = vec![
        ("conv_k3_cl1class", ConvLayer::new("c", 120, 3, 3, 10, 1, 1)),
        ("conv_k3_deep", ConvLayer::new("d", 28, 3, 64, 64, 1, 1)),
        ("conv_k5_unit", ConvLayer::new("u", 64, 5, 8, 8, 1, 2)),
        ("conv_k11_s4", ConvLayer::new("t", 127, 11, 3, 8, 4, 0)),
    ];
    for (name, layer) in &cases {
        let input = Tensor3 {
            c: layer.m,
            h: layer.h_i,
            w: layer.w_i,
            data: rng.vec_i32(layer.m * layer.h_i * layer.w_i, -96, 96),
        };
        let weights = rng.vec_i32(layer.weight_elems() as usize, -8, 8);
        let fast = EngineSim::fast(ArchConfig::small(3, 2, 2));
        let r = bench(name, 2, 5, || fast.run_layer(layer, &input, &weights).stats.macs);
        // Gmacs/s of the *functional* kernel (the analytic stats are
        // closed-form and cost nothing; wall-clock is the conv).
        let gmacs = layer.macs() as f64 / r.mean.as_secs_f64() / 1e9;
        println!("{r}");
        println!("{:<44} {:>10.2} Gmacs/s (fast-tier microkernel)", " ", gmacs);
        json.push(format!(
            "JSON {{\"bench\":\"sim_hotpath\",\"kernel\":\"{name}\",\"mean_ms\":{:.3},\
             \"gmacs_per_s\":{gmacs:.3}}}",
            r.mean.as_secs_f64() * 1e3,
        ));
    }

    for l in &json {
        println!("{l}");
    }
}
