//! Bench: the cycle-accurate simulator hot path (the §Perf L3 target) —
//! PE-array cycle updates per second across slice geometries and a small
//! engine layer.
#[path = "bench_harness.rs"]
mod harness;
use harness::{bench, header};
use trim_sa::arch::{ArchConfig, EngineSim, SliceSim};
use trim_sa::golden::Tensor3;
use trim_sa::model::ConvLayer;
use trim_sa::util::SplitMix64;

fn main() {
    header("Simulator hot path");
    let mut rng = SplitMix64::new(1);
    for (hw, k) in [(56usize, 3usize), (112, 3), (224, 3), (64, 5)] {
        let ifmap = rng.vec_i32(hw * hw, 0, 256);
        let weights = rng.vec_i32(k * k, -8, 8);
        let r = bench(&format!("slice_{hw}x{hw}_k{k}"), 1, 5, || {
            SliceSim::new(k, hw + 2).run_conv(&ifmap, hw, hw, &weights, 1, 1).stats.cycles
        });
        let cycles = SliceSim::new(k, hw + 2).run_conv(&ifmap, hw, hw, &weights, 1, 1).stats.cycles;
        let rate = cycles as f64 / r.mean.as_secs_f64() / 1e6;
        println!("{r}");
        println!("{:<44} {:>10.1} Mcycles/s  ({:.0} M PE-updates/s)", " ", rate, rate * (k * k) as f64);
    }
    let layer = ConvLayer::new("e", 28, 3, 8, 8, 1, 1);
    let input = Tensor3::from_fn(8, 28, 28, |c, y, x| ((c + y + x) % 251) as i32);
    let weights = rng.vec_i32(8 * 8 * 9, -8, 8);
    let sim = EngineSim::new(ArchConfig::small(3, 4, 4));
    println!("{}", bench("engine_28x28_m8_n8", 1, 3, || sim.run_layer(&layer, &input, &weights).stats.cycles));
}
