//! Bench: regenerate Table II (TrIM vs Eyeriss on AlexNet, kernel tiling).
#[path = "bench_harness.rs"]
mod harness;
use harness::{bench, header};
use trim_sa::analytics::trim_model::analyze_network;
use trim_sa::arch::ArchConfig;
use trim_sa::model::alexnet::alexnet;
use trim_sa::report::render_table1_or_2;

fn main() {
    header("Table II — TrIM vs Eyeriss, AlexNet");
    let cfg = ArchConfig::paper_engine();
    let net = alexnet();
    print!("{}", render_table1_or_2(&cfg, &net));
    println!("{}", bench("table2_analyze", 3, 100, || analyze_network(&cfg, &net).total_gops));
}
