//! Bench: regenerate Fig. 7 (design-space sweep over P_N, P_M).
#[path = "bench_harness.rs"]
mod harness;
use harness::{bench, header};
use trim_sa::analytics::design_space::sweep;
use trim_sa::arch::ArchConfig;
use trim_sa::model::vgg16::vgg16;
use trim_sa::report::render_fig7;

fn main() {
    header("Fig. 7 — design-space exploration");
    let cfg = ArchConfig::paper_engine();
    let net = vgg16();
    print!("{}", render_fig7(&cfg, &net));
    println!("{}", bench("fig7_sweep_25_points", 3, 50, || sweep(&cfg, &net).len()));
}
