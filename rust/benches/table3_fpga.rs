//! Bench: regenerate Table III (FPGA comparison + cost model).
#[path = "bench_harness.rs"]
mod harness;
use harness::{bench, header};
use trim_sa::analytics::fpga::{estimate, CostCoefficients};
use trim_sa::arch::ArchConfig;
use trim_sa::report::render_table3;

fn main() {
    header("Table III — FPGA comparison");
    let cfg = ArchConfig::paper_engine();
    print!("{}", render_table3(&cfg));
    println!("{}", bench("table3_cost_model", 3, 200, || estimate(&cfg, &CostCoefficients::default()).luts));
}
