//! Bench: fast vs register execution tier ([`trim_sa::arch::ExecFidelity`])
//! on FULL-SIZE layers — VGG-16 CL1 (224×224, 3→64), VGG-16 CL13 (14×14,
//! 512→512, the channel-heavy worst case: ~262k slice sweeps on the
//! register tier) and AlexNet CL1 (227×227, 11×11 stride 4 — the §V tiled
//! path). Both tiers are run on identical inputs; the bench asserts they
//! agree bit-for-bit (ofmaps) and counter-for-counter (stats) before
//! timing, so the speedup it reports is for *identical results*.
//!
//! Emits one JSON line per layer (prefixed `JSON `) for the bench
//! trajectory in EXPERIMENTS.md:
//!
//! ```text
//! JSON {"bench":"fidelity_speedup","layer":"VGG16-CL13","fast_ms":...,
//!       "register_ms":...,"speedup":...,"exact":true}
//! ```

#[path = "bench_harness.rs"]
mod harness;
use harness::{bench, header};
use std::time::Instant;
use trim_sa::arch::{ArchConfig, EngineSim};
use trim_sa::golden::Tensor3;
use trim_sa::model::{alexnet::alexnet, vgg16::vgg16, ConvLayer};
use trim_sa::util::SplitMix64;

fn main() {
    header("fidelity speedup — fast vs register tier on full-size layers");
    let cfg = ArchConfig::paper_engine();
    let register = EngineSim::new(cfg);
    let fast = EngineSim::fast(cfg);
    let cases: Vec<(&str, ConvLayer)> = vec![
        ("VGG16-CL1", vgg16().layers[0].clone()),
        ("VGG16-CL13", vgg16().layers[12].clone()),
        ("AlexNet-CL1", alexnet().layers[0].clone()),
    ];
    let mut json = Vec::new();
    for (name, layer) in &cases {
        let mut rng = SplitMix64::new(0xF1DE);
        let input = Tensor3 {
            c: layer.m,
            h: layer.h_i,
            w: layer.w_i,
            data: rng.vec_i32(layer.m * layer.h_i * layer.w_i, 0, 256),
        };
        let weights = rng.vec_i32(layer.weight_elems() as usize, -8, 8);

        // One register run serves as both the timed measurement (it is
        // deterministic and seconds-long at full size — don't pay for it
        // twice) and the exactness oracle for the fast tier.
        let t0 = Instant::now();
        let rr = register.run_layer(layer, &input, &weights);
        let register_s = t0.elapsed().as_secs_f64();
        let rf = fast.run_layer(layer, &input, &weights);
        let exact = rf.ofmaps == rr.ofmaps && rf.stats == rr.stats;
        assert!(exact, "{name}: fast tier diverged from the register oracle");

        let fast_r = bench(&format!("{name} fast"), 1, 5, || fast.run_layer(layer, &input, &weights));
        println!("{fast_r}");
        let speedup = register_s / fast_r.mean.as_secs_f64();
        println!(
            "{name}: register {:.1} ms -> fast {:.3} ms = {speedup:.1}x (bit- and counter-exact)\n",
            register_s * 1e3,
            fast_r.mean.as_secs_f64() * 1e3,
        );
        json.push(format!(
            "JSON {{\"bench\":\"fidelity_speedup\",\"layer\":\"{name}\",\"fast_ms\":{:.3},\
             \"register_ms\":{:.3},\"speedup\":{:.1},\"exact\":{exact}}}",
            fast_r.mean.as_secs_f64() * 1e3,
            register_s * 1e3,
            speedup,
        ));
    }
    for l in &json {
        println!("{l}");
    }
}
