//! VGG-16 analysis: regenerate the paper's evaluation artefacts for the
//! flagship workload — Fig. 1, Fig. 7, Table I and the §V headlines.
//!
//! Run with: `cargo run --release --example vgg16_analysis`

use trim_sa::analytics::design_space::evaluate;
use trim_sa::analytics::trim_model::analyze_network;
use trim_sa::arch::ArchConfig;
use trim_sa::model::vgg16::vgg16;
use trim_sa::report::{render_fig1, render_fig7, render_table1_or_2, render_table3};

fn main() {
    let cfg = ArchConfig::paper_engine();
    let net = vgg16();

    println!("{}", render_fig1(&net, 8));
    println!("{}", render_table1_or_2(&cfg, &net));
    println!("{}", render_fig7(&cfg, &net));
    println!("{}", render_table3(&cfg));

    // §V headlines, side by side with the paper.
    let m = analyze_network(&cfg, &net);
    println!("§V headline checks (model vs paper):");
    println!("  peak throughput  : {:>7.1} GOPs/s   (paper 453.6)", cfg.peak_ops_per_s() / 1e9);
    println!("  VGG-16 sustained : {:>7.1} GOPs/s   (paper 391)", m.total_gops);
    println!("  VGG-16 inference : {:>7.1} ms       (paper 78.6)", m.total_time_s * 1e3);
    println!("  mean utilisation : {:>7.2}          (paper 0.93)", m.mean_utilization);
    println!(
        "  accesses vs Eyeriss: {:>5.2}x fewer   (paper ~3x)",
        (trim_sa::analytics::eyeriss::PUBLISHED_VGG16_TOTAL.on_chip_m
            + trim_sa::analytics::eyeriss::PUBLISHED_VGG16_TOTAL.off_chip_m)
            / m.total_m()
    );

    // §IV: the iso-PE design-point comparison.
    let a = evaluate(&cfg, &net, 4, 16);
    let b = evaluate(&cfg, &net, 16, 4);
    println!("\n§IV iso-PE comparison (both 576 PEs):");
    println!(
        "  (P_N=4,  P_M=16): {:>6.1} GOPs/s, psum {:>5.2} Mbit, BW {:>4} bits/cycle",
        a.gops, a.psum_buffer_mbit, a.io_bandwidth_bits
    );
    println!(
        "  (P_N=16, P_M=4 ): {:>6.1} GOPs/s, psum {:>5.2} Mbit, BW {:>4} bits/cycle",
        b.gops, b.psum_buffer_mbit, b.io_bandwidth_bits
    );
}
