//! AlexNet large-kernel tiling: exercise the §V kernel-decomposition path
//! (11×11 and 5×5 kernels on 3×3 slices) end to end — schedule, cycle
//! model, Table II, and a bit-exact tiled engine run.
//!
//! Run with: `cargo run --release --example alexnet_tiling`

use trim_sa::arch::control::plan_layer;
use trim_sa::arch::{ArchConfig, EngineSim};
use trim_sa::golden::{conv3d_i32, Tensor3};
use trim_sa::model::{alexnet::alexnet, ConvLayer, KernelTiling};
use trim_sa::report::render_table1_or_2;

fn main() {
    let cfg = ArchConfig::paper_engine();
    let net = alexnet();

    println!("kernel tiling on the {}x{} native slice:", cfg.k, cfg.k);
    for l in &net.layers {
        let t = KernelTiling::new(l.k, cfg.k);
        let plan = plan_layer(&cfg, l);
        println!(
            "  {}: K={:<2} -> {:>2} tiles (fill {:>5.1}%), {} cooperating cores, {} filters in parallel, util {:.2}",
            l.name,
            l.k,
            t.num_tiles(),
            t.fill_ratio() * 100.0,
            plan.cores_per_filter,
            plan.filters_parallel,
            plan.utilization
        );
    }

    println!("\n{}", render_table1_or_2(&cfg, &net));

    // Bit-exact check of the tiled path on an AlexNet-CL1-shaped (scaled)
    // layer: 11×11 kernel, stride 4 — every tile convolves a shifted view
    // and the engine accumulates, reproducing the full convolution.
    let layer = ConvLayer::new("CL1-scaled", 39, 11, 3, 4, 4, 0);
    let input = Tensor3::from_fn(3, 39, 39, |c, y, x| ((c * 67 + y * 13 + x * 3) % 256) as i32);
    let weights: Vec<i32> = (0..4 * 3 * 121).map(|i| ((i as i32 * 29) % 17) - 8).collect();
    let sim = EngineSim::new(ArchConfig::small(3, 4, 2));
    let r = sim.run_layer(&layer, &input, &weights);
    let golden = conv3d_i32(&input, &weights, 4, 11, 4, 0);
    assert_eq!(r.ofmaps, golden);
    println!(
        "tiled 11x11 stride-4 engine run: bit-exact vs golden ({} tiles, {} psum-buffer accesses)",
        r.plan.tiles,
        r.stats.on_chip_accesses()
    );
}
