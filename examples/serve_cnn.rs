//! End-to-end driver (DESIGN.md §5 "e2e"): serve batched CNN inference
//! through the full three-layer stack —
//!
//!   Pallas kernel (L1) → JAX block (L2) → HLO text artifact →
//!   Rust PJRT runtime → coordinator (L3) with dynamic batching.
//!
//! With `make artifacts` + the `pjrt` cargo feature this exercises the
//! compiled-artifact path and cross-checks the block pipeline against the
//! fused whole-network artifact. Without them (the offline default) it
//! prints a notice and serves the same workload from the simulated TrIM
//! engine farm instead — the example always runs.
//!
//! Run with: `cargo run --release --example serve_cnn [-- <artifact-dir>]`

use std::time::Duration;
use trim_sa::coordinator::{make_backend, BackendKind, BatcherConfig, Coordinator, CoordinatorConfig};
use trim_sa::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let sim_engines = 4;

    // --- cross-check: block pipeline == fused forward, natively ---------
    // Only possible when the PJRT runtime and artifacts are present; the
    // serving section below works either way.
    match Runtime::load(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {} | modules: {:?}", rt.platform(), rt.module_names());
            let input_len = rt.module("trimnet_block0")?.spec.inputs[0].elems();
            let image: Vec<i32> = (0..input_len).map(|j| ((j * 31 + 7) % 256) as i32).collect();
            let mut act = image.clone();
            for b in 0..3 {
                act = rt.module(&format!("trimnet_block{b}"))?.run_i32(&[&act])?;
            }
            let blockwise = rt.module("trimnet_head")?.run_i32(&[&act])?;
            let fused = rt.module("trimnet_full")?.run_i32(&[&image])?;
            assert_eq!(blockwise, fused, "serving pipeline must equal the fused artifact");
            println!("blockwise pipeline == fused forward artifact (logits {blockwise:?})");
        }
        Err(e) => {
            println!("notice: PJRT artifacts unavailable ({e:#})");
            println!("notice: skipping the artifact cross-check; serving falls back to the sim engine farm");
        }
    }

    // --- serve a workload through the coordinator -----------------------
    let n_requests = 96;
    for max_batch in [1usize, 8] {
        let cfg = CoordinatorConfig {
            batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(2) },
            ..Default::default()
        };
        let d = dir.clone();
        let c = Coordinator::start_with(
            move || {
                make_backend(
                    BackendKind::Auto,
                    &d,
                    sim_engines,
                    trim_sa::arch::ExecFidelity::Fast,
                    trim_sa::scheduler::ShardMode::Auto,
                    0.0, // no shadow canary in the example
                )
            },
            cfg,
        )?;
        if max_batch == 1 {
            println!("backend: {}", c.backend_description());
        }
        let input_len = c.input_len();
        let t0 = std::time::Instant::now();
        let pending: Vec<_> = (0..n_requests)
            .map(|i| {
                let img: Vec<i32> =
                    (0..input_len).map(|j| ((i * 7919 + j * 31) % 256) as i32).collect();
                c.submit(img).unwrap()
            })
            .collect();
        for rx in pending {
            rx.recv()??;
        }
        let wall = t0.elapsed();
        let m = c.metrics();
        println!(
            "max_batch={max_batch:<2} | {n_requests} reqs in {:>6.1} ms | {:>6.1} req/s | p50 {:>7.1?} p95 {:>7.1?} | {} batches (mean {:.1})",
            wall.as_secs_f64() * 1e3,
            n_requests as f64 / wall.as_secs_f64(),
            m.p50_latency,
            m.p95_latency,
            m.batches,
            m.mean_batch
        );
        // Sim-backed serving carries the paper's cost accounting through
        // the response path; PJRT-backed serving has no simulated cost.
        if m.sim_batches > 0 {
            println!(
                "             | sim cost: {} cycles | {} off-chip + {} on-chip accesses | {:.3} mJ | {:.2} GOPs/s",
                m.sim_cycles,
                m.sim_off_chip_accesses,
                m.sim_on_chip_accesses,
                m.sim_joules * 1e3,
                m.sim_gops
            );
        }
    }
    println!("e2e serving OK — record results in EXPERIMENTS.md");
    Ok(())
}
