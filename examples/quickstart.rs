//! Quickstart: simulate one TrIM slice on a small convolution, check it
//! against the golden model, and read off the dataflow's headline
//! properties (everything *measured* by the register-accurate simulator).
//!
//! Run with: `cargo run --release --example quickstart`

use trim_sa::arch::SliceSim;
use trim_sa::golden::conv2d_i32;

fn main() {
    // A 3×3 convolution over a 28×28 ifmap with 'same' padding — one VGG
    // CL13-class slice task.
    let (h, w, k, pad) = (28usize, 28usize, 3usize, 1usize);
    let ifmap: Vec<i32> = (0..h * w).map(|i| (i as i32 * 13 + 1) % 256).collect();
    let weights: Vec<i32> = vec![1, 0, -1, 2, 0, -2, 1, 0, -1]; // Sobel-x

    // 1. register-accurate slice simulation
    let mut slice = SliceSim::new(k, w + 2 * pad);
    let result = slice.run_conv(&ifmap, h, w, &weights, pad, 1);

    // 2. golden check
    let golden = conv2d_i32(&ifmap, h, w, &weights, k, 1, pad);
    assert_eq!(result.output, golden, "simulator must be bit-exact");
    println!("slice output == golden direct convolution ({}x{} ofmap)", result.h_o, result.w_o);

    // 3. the dataflow properties the paper claims, as measured:
    let s = &result.stats;
    println!("cycles                    : {}", s.cycles);
    println!("external input reads      : {} (padded ifmap read exactly once: {})",
        s.ext_input_reads, s.ext_input_reads == ((h + 2 * pad) * (w + 2 * pad)) as u64);
    println!("input-read overhead       : {:.2}% (the paper's 'negligible overhead')",
        s.input_read_overhead((h * w) as u64) * 100.0);
    println!("peak ext inputs per cycle : {} (eq. 4's '5' for K=3)", s.peak_ext_inputs_per_cycle);
    println!("max RSRB occupancy        : {} (≤ one padded row = {})", s.max_rsrb_occupancy, w + 2 * pad);
    println!("MACs performed            : {}", s.macs);
}
