//! Repo lint pass: `cargo xtask lint` (or `cargo run --manifest-path
//! xtask/Cargo.toml -- lint`).
//!
//! A std-only *lexical* scanner over `rust/src` — no `syn`, no
//! dependencies, so it runs in the offline container — enforcing three
//! repo-specific invariants that clippy cannot express:
//!
//! 1. **No panics on serving paths.** Files under `coordinator/` (plus
//!    `fault.rs`, whose ABFT/self-healing machinery runs inside every
//!    shard merge, and `farm.rs`, whose merge loop, hedging rendezvous
//!    and health accounting sit under every served request) must not
//!    call `.unwrap()` / `.expect(` / `panic!` / `unreachable!` / `todo!`
//!    outside `#[cfg(test)]` regions: every request must resolve with a
//!    typed [`ServeError`] instead of tearing the engine thread down. A
//!    `// lint: test-double` marker on the same or preceding line exempts
//!    deliberate fault-injection fixtures.
//! 2. **No allocation on `// lint: hot-path` functions.** The fastsim
//!    microkernels (`conv_taps_*`) are the per-batch inner loops; a
//!    stray `vec!`/`format!`/`.clone()` there would silently cost more
//!    than the arithmetic. The marker comment binds to the next `fn` and
//!    its whole body.
//! 3. **`#[must_use]` on `ServeResult`-returning public APIs.** Dropping
//!    the reply receiver loses the request's response; the attribute (with
//!    a message, to stay clear of clippy's `double_must_use`) makes the
//!    compiler say so.
//!
//! Output: one `LINT file:line: rule: message` line per violation,
//! nonzero exit when any fire — wired as a required CI gate next to
//! clippy.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut root: Option<PathBuf> = None;
    let mut cmd: Option<&str> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "lint" => cmd = Some("lint"),
            "--root" => root = it.next().map(PathBuf::from),
            other => {
                eprintln!("unknown argument {other:?}");
                return usage();
            }
        }
    }
    let Some("lint") = cmd else { return usage() };
    // The xtask crate lives at <repo>/xtask; the scanned tree at
    // <repo>/rust/src.
    let root = root.unwrap_or_else(|| {
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().map(PathBuf::from).unwrap_or_default()
    });
    let src = root.join("rust").join("src");
    if !src.is_dir() {
        eprintln!("lint root {} has no rust/src", root.display());
        return ExitCode::FAILURE;
    }
    let mut files = Vec::new();
    collect_rs_files(&src, &mut files);
    files.sort();
    let mut violations = Vec::new();
    for f in &files {
        match std::fs::read_to_string(f) {
            Ok(text) => lint_file(f, &src, &text, &mut violations),
            Err(e) => violations.push(Violation {
                file: f.clone(),
                line: 0,
                rule: "io",
                message: format!("unreadable: {e}"),
            }),
        }
    }
    for v in &violations {
        println!("LINT {}:{}: {}: {}", v.file.display(), v.line, v.rule, v.message);
    }
    println!(
        "xtask lint: {} file(s) scanned, {} violation(s)",
        files.len(),
        violations.len()
    );
    if violations.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn usage() -> ExitCode {
    eprintln!("usage: xtask lint [--root REPO_ROOT]");
    ExitCode::FAILURE
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            collect_rs_files(&p, out);
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
}

struct Violation {
    file: PathBuf,
    line: usize,
    rule: &'static str,
    message: String,
}

/// Tokens forbidden on serving paths (rule 1). `.unwrap()` is matched
/// with its closing paren so `.unwrap_or(..)` / `.unwrap_or_else(..)` —
/// the *correct* spellings — never fire.
const PANIC_TOKENS: &[&str] = &[".unwrap()", ".expect(", "panic!", "unreachable!", "todo!"];

/// Allocation-capable calls forbidden inside `// lint: hot-path` bodies.
const ALLOC_TOKENS: &[&str] = &[
    "vec!",
    "Box::new",
    "String::",
    "format!",
    ".to_vec(",
    ".to_string(",
    ".to_owned(",
    "with_capacity(",
    ".collect(",
    ".push(",
    ".resize(",
    ".clone(",
];

fn lint_file(path: &Path, src_root: &Path, text: &str, out: &mut Vec<Violation>) {
    let sanitized = sanitize(text);
    debug_assert_eq!(sanitized.len(), text.len(), "sanitizer must preserve byte offsets");
    let raw_lines: Vec<&str> = text.lines().collect();
    let line_of = |byte: usize| text[..byte].bytes().filter(|&b| b == b'\n').count() + 1;
    let test_regions = cfg_test_regions(&sanitized);
    let in_tests = |byte: usize| test_regions.iter().any(|r| r.contains(&byte));
    let rel = path.strip_prefix(src_root).unwrap_or(path);
    // Serving paths must stay panic-free; fault.rs joins them because the
    // ABFT/self-healing machinery runs inside every shard merge — a panic
    // there would turn a detected hardware fault into a dead engine — and
    // farm.rs because its merge loop, hedging rendezvous and health
    // accounting sit under every served request.
    let serving_path = rel.components().any(|c| c.as_os_str() == "coordinator")
        || rel.file_name().is_some_and(|f| f == "fault.rs" || f == "farm.rs");

    // Rule 1: no panic-capable calls on serving paths.
    if serving_path {
        for tok in PANIC_TOKENS {
            for at in find_all(&sanitized, tok) {
                if in_tests(at) {
                    continue;
                }
                let line = line_of(at);
                if marked(&raw_lines, line, "lint: test-double") {
                    continue;
                }
                out.push(Violation {
                    file: path.to_path_buf(),
                    line,
                    rule: "serving-no-panic",
                    message: format!(
                        "{tok} on a serving path — propagate a typed ServeError instead \
                         (or mark a deliberate fixture with `// lint: test-double`)"
                    ),
                });
            }
        }
    }

    // Rule 2: no allocation in `// lint: hot-path` functions.
    for (idx, raw) in raw_lines.iter().enumerate() {
        if !raw.contains("lint: hot-path") {
            continue;
        }
        let marker_byte: usize = raw_lines[..idx].iter().map(|l| l.len() + 1).sum();
        let Some(body) = next_fn_body(&sanitized, marker_byte) else {
            out.push(Violation {
                file: path.to_path_buf(),
                line: idx + 1,
                rule: "hot-path",
                message: "`// lint: hot-path` marker with no following fn".into(),
            });
            continue;
        };
        let slice = &sanitized[body.clone()];
        for tok in ALLOC_TOKENS {
            for at in find_all(slice, tok) {
                out.push(Violation {
                    file: path.to_path_buf(),
                    line: line_of(body.start + at),
                    rule: "hot-path",
                    message: format!("allocation-capable call {tok} in a hot-path function"),
                });
            }
        }
    }

    // Rule 3: `#[must_use]` on public fns returning ServeResult (directly
    // or wrapped, e.g. `Result<mpsc::Receiver<ServeResult>>`).
    for at in find_all(&sanitized, "pub fn ") {
        if in_tests(at) {
            continue;
        }
        // Signature: from `pub fn` to the body `{` (or `;` for trait
        // methods without bodies).
        let sig_end = sanitized[at..]
            .find(['{', ';'])
            .map(|o| at + o)
            .unwrap_or(sanitized.len());
        let sig = &sanitized[at..sig_end];
        let returns_serve_result =
            sig.find("->").is_some_and(|arrow| sig[arrow..].contains("ServeResult"));
        if !returns_serve_result {
            continue;
        }
        let line = line_of(at);
        let lookback = line.saturating_sub(8)..line;
        let has_must_use =
            lookback.clone().any(|l| raw_lines.get(l.wrapping_sub(1)).is_some_and(|r| r.contains("#[must_use")))
                || raw_lines.get(line - 1).is_some_and(|r| r.contains("#[must_use"));
        if !has_must_use {
            out.push(Violation {
                file: path.to_path_buf(),
                line,
                rule: "must-use-serve-result",
                message: "public fn returns ServeResult without #[must_use = \"...\"] — \
                          dropping the receiver loses the reply"
                    .into(),
            });
        }
    }
}

/// True when `needle` appears on the violation's own line or the line
/// above it (1-based `line`).
fn marked(raw_lines: &[&str], line: usize, needle: &str) -> bool {
    let same = raw_lines.get(line - 1).is_some_and(|l| l.contains(needle));
    let above = line >= 2 && raw_lines.get(line - 2).is_some_and(|l| l.contains(needle));
    same || above
}

fn find_all(haystack: &str, needle: &str) -> Vec<usize> {
    let mut hits = Vec::new();
    let mut from = 0;
    while let Some(off) = haystack[from..].find(needle) {
        hits.push(from + off);
        from += off + needle.len();
    }
    hits
}

/// Byte range of the body (including braces) of the first `fn` at or
/// after `from` in sanitized text.
fn next_fn_body(sanitized: &str, from: usize) -> Option<std::ops::Range<usize>> {
    let fn_at = find_all(&sanitized[from..], "fn ").first().map(|o| from + o)?;
    let open = sanitized[fn_at..].find('{').map(|o| fn_at + o)?;
    let close = match_brace(sanitized, open)?;
    Some(open..close + 1)
}

/// Byte ranges covered by `#[cfg(test)]` items (the attribute through the
/// matching close brace of the item it decorates).
fn cfg_test_regions(sanitized: &str) -> Vec<std::ops::Range<usize>> {
    let mut regions = Vec::new();
    for at in find_all(sanitized, "#[cfg(test)]") {
        if let Some(open) = sanitized[at..].find('{').map(|o| at + o) {
            if let Some(close) = match_brace(sanitized, open) {
                regions.push(at..close + 1);
            }
        }
    }
    regions
}

/// Index of the `}` matching the `{` at `open` (sanitized text, so
/// braces inside strings/comments are already blanked).
fn match_brace(sanitized: &str, open: usize) -> Option<usize> {
    let bytes = sanitized.as_bytes();
    let mut depth = 0usize;
    for (i, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Blank comments and string/char-literal contents with spaces,
/// preserving length and newlines, so token search and brace matching
/// never fire inside them. Handles `//`, nested `/* */`, `"…"` with
/// escapes, raw strings `r#"…"#`, byte strings, and char literals vs
/// lifetimes.
fn sanitize(text: &str) -> String {
    let b = text.as_bytes();
    let mut out = vec![b' '; b.len()];
    let mut i = 0;
    let n = b.len();
    let keep_newlines = |out: &mut [u8], from: usize, to: usize, src: &[u8]| {
        for j in from..to {
            if src[j] == b'\n' {
                out[j] = b'\n';
            }
        }
    };
    while i < n {
        match b[i] {
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                let end = text[i..].find('\n').map(|o| i + o).unwrap_or(n);
                keep_newlines(&mut out, i, end, b);
                i = end;
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                let start = i;
                let mut depth = 1usize;
                i += 2;
                while i < n && depth > 0 {
                    if i + 1 < n && b[i] == b'/' && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if i + 1 < n && b[i] == b'*' && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                keep_newlines(&mut out, start, i, b);
            }
            b'r' | b'b'
                if is_raw_string_start(b, i) =>
            {
                // r"…", r#"…"#, br"…", …: copy the opener, blank contents.
                let mut j = i;
                out[j] = b[j];
                j += 1;
                if b[j] == b'r' {
                    out[j] = b[j];
                    j += 1;
                }
                let mut hashes = 0;
                while j < n && b[j] == b'#' {
                    out[j] = b'#';
                    hashes += 1;
                    j += 1;
                }
                out[j] = b'"'; // opening quote
                j += 1;
                let closer: Vec<u8> =
                    std::iter::once(b'"').chain(std::iter::repeat(b'#').take(hashes)).collect();
                while j < n {
                    if b[j..].starts_with(&closer) {
                        for (k, &cb) in closer.iter().enumerate() {
                            out[j + k] = cb;
                        }
                        j += closer.len();
                        break;
                    }
                    if b[j] == b'\n' {
                        out[j] = b'\n';
                    }
                    j += 1;
                }
                i = j;
            }
            b'"' => {
                out[i] = b'"';
                i += 1;
                while i < n {
                    if b[i] == b'\\' {
                        i += 2;
                        continue;
                    }
                    if b[i] == b'"' {
                        out[i] = b'"';
                        i += 1;
                        break;
                    }
                    if b[i] == b'\n' {
                        out[i] = b'\n';
                    }
                    i += 1;
                }
            }
            b'\'' => {
                // Char literal vs lifetime: 'x' / '\n' close with a quote;
                // 'a (lifetime) does not.
                if i + 1 < n && b[i + 1] == b'\\' {
                    out[i] = b'\'';
                    i += 2; // skip the backslash + escaped char
                    while i < n && b[i] != b'\'' {
                        i += 1;
                    }
                    if i < n {
                        out[i] = b'\'';
                        i += 1;
                    }
                } else if i + 2 < n && b[i + 2] == b'\'' {
                    out[i] = b'\'';
                    out[i + 2] = b'\'';
                    i += 3;
                } else {
                    out[i] = b'\'';
                    i += 1; // lifetime: keep scanning normally
                }
            }
            c => {
                out[i] = c;
                i += 1;
            }
        }
    }
    String::from_utf8(out).unwrap_or_default()
}

/// True at `r"`, `r#`-quote, `br"`, `br#`-quote (raw string openers).
fn is_raw_string_start(b: &[u8], i: usize) -> bool {
    let rest = &b[i..];
    let after_prefix = if rest.starts_with(b"br") || rest.starts_with(b"rb") {
        &rest[2..]
    } else if rest.starts_with(b"r") || rest.starts_with(b"b") {
        if rest.starts_with(b"b") && !rest[1..].starts_with(b"\"") {
            // b"…" is a plain byte string — handled by the '"' arm; `b`
            // followed by anything else is an identifier.
            return false;
        }
        if rest.starts_with(b"b") {
            return false; // plain byte string, not raw
        }
        &rest[1..]
    } else {
        return false;
    };
    // Must be a real raw opener: optional #s then a quote — and the `r`
    // must not be the tail of an identifier (e.g. `for`, `ptr`).
    let mut j = 0;
    while j < after_prefix.len() && after_prefix[j] == b'#' {
        j += 1;
    }
    let opener_ok = after_prefix.get(j) == Some(&b'"');
    let boundary_ok = i == 0 || !(b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_');
    opener_ok && boundary_ok
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_blanks_comments_and_strings() {
        let src = "let a = \"panic!\"; // panic!\nlet b = 1; /* .unwrap() */\n";
        let s = sanitize(src);
        assert_eq!(s.len(), src.len());
        assert!(!s.contains("panic!"), "got {s:?}");
        assert!(!s.contains(".unwrap()"));
        assert!(s.contains("let a"));
        assert_eq!(s.matches('\n').count(), 2);
    }

    #[test]
    fn sanitize_handles_char_literals_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) -> char { '{' }";
        let s = sanitize(src);
        assert!(!s.contains("'{'"), "brace inside char literal must be blanked: {s:?}");
        assert!(s.contains("fn f<'a>"));
        assert_eq!(match_brace(&s, s.find('{').unwrap()), Some(src.len() - 1));
    }

    #[test]
    fn cfg_test_region_covers_the_mod() {
        let src = "fn live() { x.unwrap() }\n#[cfg(test)]\nmod tests {\n fn t() { y.unwrap() }\n}\n";
        let s = sanitize(src);
        let regions = cfg_test_regions(&s);
        assert_eq!(regions.len(), 1);
        let hits = find_all(&s, ".unwrap()");
        assert_eq!(hits.len(), 2);
        assert!(!regions[0].contains(&hits[0]), "live code is outside the region");
        assert!(regions[0].contains(&hits[1]), "test code is inside the region");
    }

    #[test]
    fn hot_path_marker_binds_to_next_fn() {
        let src = "// lint: hot-path\n#[inline]\nfn hot(v: &mut Vec<u32>) { v.push(1) }\nfn cold() { let _ = vec![1]; }\n";
        let s = sanitize(src);
        let body = next_fn_body(&s, 0).unwrap();
        assert!(s[body.clone()].contains(".push("));
        assert!(!s[body].contains("vec!"), "the next fn only, not the one after");
    }
}
