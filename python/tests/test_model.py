"""L2 correctness: quantised layers, TrimNet blocks and the forward pass."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels.ref import conv3d_ref, pad_hw, requant_ref

jax.config.update("jax_platform_name", "cpu")


def rand_x(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 256, size=shape), jnp.int32)


def test_conv_layer_matches_ref_pipeline():
    x = rand_x((3, 12, 12), 1)
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.integers(-8, 8, size=(5, 3, 3, 3)), jnp.int32)
    got = model.conv_layer(x, w, pad=1, shift=7)
    ref = requant_ref(conv3d_ref(pad_hw(x, 1), w), 7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert int(jnp.min(got)) >= 0 and int(jnp.max(got)) <= 255


def test_maxpool2():
    x = jnp.arange(2 * 4 * 4, dtype=jnp.int32).reshape(2, 4, 4)
    y = model.maxpool2(x)
    assert y.shape == (2, 2, 2)
    np.testing.assert_array_equal(np.asarray(y[0]), [[5, 7], [13, 15]])


def test_maxpool2_odd_sizes_truncate():
    x = jnp.ones((1, 5, 7), jnp.int32)
    assert model.maxpool2(x).shape == (1, 2, 3)


def test_head_is_integer_linear():
    x = jnp.ones((4, 2, 2), jnp.int32)
    w = jnp.eye(4, 3, dtype=jnp.int32)
    logits = model.head(x, w)
    # sum-pool of ones over 2×2 = 4 per channel; identity-ish weights
    np.testing.assert_array_equal(np.asarray(logits), [4, 4, 4])


def test_block_io_shapes_consistent():
    shapes = model.block_io_shapes()
    assert shapes[0][0] == model.TRIMNET_INPUT
    for (_, out), (nxt, _) in zip(shapes[:-2], shapes[1:-1]):
        assert out == nxt, "block outputs must chain"
    assert shapes[-1][1] == (model.TRIMNET_CLASSES,)


def test_trimnet_forward_shapes_and_determinism():
    ws, w_fc = model.trimnet_weights(seed=0)
    ws2, w_fc2 = model.trimnet_weights(seed=0)
    for a, b in zip(ws, ws2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(w_fc), np.asarray(w_fc2))

    x = rand_x(model.TRIMNET_INPUT, 7)
    logits = model.trimnet_forward(x, ws, w_fc)
    assert logits.shape == (model.TRIMNET_CLASSES,)


def test_trimnet_blockwise_equals_full_forward():
    """The serving path (per-block artifacts chained by the Rust
    coordinator) must be numerically identical to the fused forward."""
    ws, w_fc = model.trimnet_weights(seed=0)
    x = rand_x(model.TRIMNET_INPUT, 11)
    full = model.trimnet_forward(x, ws, w_fc)
    y = x
    for w, spec in zip(ws, model.TRIMNET_SPECS):
        y = model.trimnet_block(y, w, spec)
    blockwise = model.head(y, w_fc)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(blockwise))


def test_trimnet_activations_stay_in_range():
    ws, w_fc = model.trimnet_weights(seed=0)
    x = rand_x(model.TRIMNET_INPUT, 13)
    y = x
    for w, spec in zip(ws, model.TRIMNET_SPECS):
        y = model.trimnet_block(y, w, spec)
        assert int(jnp.min(y)) >= 0 and int(jnp.max(y)) <= 255, spec
