"""L1 correctness: Pallas TrIM kernels vs the pure-jnp oracle.

Fixed cases pin known geometries (VGG-like, AlexNet-tile-like); hypothesis
sweeps shapes, channel counts and value ranges. This is the CORE
correctness signal for the compile path — the same kernels are lowered
into every artifact the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import trim_conv
from compile.kernels.ref import conv2d_ref, conv3d_ref, pad_hw, requant_ref

jax.config.update("jax_platform_name", "cpu")


def rand_ifmap(rng, shape, bits=8):
    return jnp.asarray(rng.integers(0, 1 << bits, size=shape), jnp.int32)


def rand_weights(rng, shape, bits=8):
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1))
    return jnp.asarray(rng.integers(lo, hi, size=shape), jnp.int32)


# ---------------------------------------------------------------- conv2d --
@pytest.mark.parametrize("h,w,k", [(8, 8, 3), (12, 9, 3), (10, 10, 5), (6, 14, 2), (31, 31, 3)])
def test_conv2d_matches_ref(h, w, k):
    rng = np.random.default_rng(h * 100 + w * 10 + k)
    x = rand_ifmap(rng, (h, w))
    wgt = rand_weights(rng, (k, k))
    got = trim_conv2d = trim_conv.trim_conv2d(x, wgt)
    ref = conv2d_ref(x, wgt)
    np.testing.assert_array_equal(np.asarray(trim_conv2d), np.asarray(ref))
    assert got.dtype == jnp.int32


def test_conv2d_identity_kernel():
    rng = np.random.default_rng(0)
    x = rand_ifmap(rng, (7, 7))
    k = jnp.zeros((3, 3), jnp.int32).at[1, 1].set(1)
    got = trim_conv.trim_conv2d(pad_hw(x, 1), k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


@settings(deadline=None, max_examples=25)
@given(
    h=st.integers(5, 16),
    w=st.integers(5, 16),
    k=st.sampled_from([2, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_hypothesis_sweep(h, w, k, seed):
    if h < k or w < k:
        return
    rng = np.random.default_rng(seed)
    x = rand_ifmap(rng, (h, w))
    wgt = rand_weights(rng, (k, k))
    np.testing.assert_array_equal(
        np.asarray(trim_conv.trim_conv2d(x, wgt)), np.asarray(conv2d_ref(x, wgt))
    )


# ---------------------------------------------------------------- conv3d --
@pytest.mark.parametrize(
    "m,n,h,w,k",
    [(1, 1, 8, 8, 3), (3, 4, 10, 10, 3), (4, 2, 8, 12, 3), (2, 3, 9, 9, 5), (8, 8, 6, 6, 3)],
)
def test_conv3d_matches_ref(m, n, h, w, k):
    rng = np.random.default_rng(m * 1000 + n * 100 + h)
    x = rand_ifmap(rng, (m, h, w))
    wgt = rand_weights(rng, (n, m, k, k))
    np.testing.assert_array_equal(
        np.asarray(trim_conv.trim_conv3d(x, wgt)), np.asarray(conv3d_ref(x, wgt))
    )


def test_conv3d_channel_sum_semantics():
    # Two channels of ones with centre-1 kernels → output = 2 everywhere.
    x = jnp.ones((2, 6, 6), jnp.int32)
    w = jnp.zeros((1, 2, 3, 3), jnp.int32).at[:, :, 1, 1].set(1)
    got = trim_conv.trim_conv3d(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.full((1, 4, 4), 2))


@settings(deadline=None, max_examples=20)
@given(
    m=st.integers(1, 5),
    n=st.integers(1, 5),
    h=st.integers(4, 10),
    w=st.integers(4, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv3d_hypothesis_sweep(m, n, h, w, seed):
    k = 3
    if h < k or w < k:
        return
    rng = np.random.default_rng(seed)
    x = rand_ifmap(rng, (m, h, w))
    wgt = rand_weights(rng, (n, m, k, k))
    np.testing.assert_array_equal(
        np.asarray(trim_conv.trim_conv3d(x, wgt)), np.asarray(conv3d_ref(x, wgt))
    )


@settings(deadline=None, max_examples=10)
@given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1))
def test_conv3d_dtype_ranges(bits, seed):
    """Sweep operand precision B (the paper's datapath is parametric in B)."""
    rng = np.random.default_rng(seed)
    x = rand_ifmap(rng, (2, 6, 6), bits)
    wgt = rand_weights(rng, (2, 2, 3, 3), bits)
    np.testing.assert_array_equal(
        np.asarray(trim_conv.trim_conv3d(x, wgt)), np.asarray(conv3d_ref(x, wgt))
    )


# --------------------------------------------------------------- requant --
def test_requant_matches_rust_semantics():
    acc = jnp.asarray([0, 16, 23, 24, -100, 1 << 30], jnp.int32)
    got = requant_ref(acc, shift=4, bits=8)
    np.testing.assert_array_equal(np.asarray(got), [0, 1, 1, 2, 0, 255])


def test_requant_zero_shift():
    acc = jnp.asarray([17, 300, -5], jnp.int32)
    np.testing.assert_array_equal(np.asarray(requant_ref(acc, 0)), [17, 255, 0])


# ------------------------------------------------------------ ref oracle --
def test_ref_strided_conv():
    x = jnp.ones((1, 8, 8), jnp.int32)
    w = jnp.ones((1, 1, 2, 2), jnp.int32)
    out = conv3d_ref(x, w, stride=2)
    assert out.shape == (1, 4, 4)
    np.testing.assert_array_equal(np.asarray(out), np.full((1, 4, 4), 4))


def test_vmem_footprint_estimate_is_positive_and_small():
    # VGG CL2-like window: M=64, W_P=226 → must fit VMEM (16 MiB class).
    b = trim_conv.vmem_footprint_bytes(m=64, w_p=226, n=64, k=3)
    assert 0 < b < 16 * 2**20
