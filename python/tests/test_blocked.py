"""Blocked-kernel variants vs the plain kernel and the ref oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import blocked, trim_conv
from compile.kernels.ref import conv3d_ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, lo, hi, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=shape), jnp.int32)


@pytest.mark.parametrize("m,n,mb,nb", [(8, 8, 4, 4), (16, 8, 8, 8), (4, 4, 2, 4), (8, 16, 8, 2)])
def test_blocked_equals_plain(m, n, mb, nb):
    x = rand((m, 10, 10), 0, 256, m * 100 + n)
    w = rand((n, m, 3, 3), -8, 8, n * 10 + m)
    plain = trim_conv.trim_conv3d(x, w)
    blk = blocked.trim_conv3d_blocked(x, w, m_block=mb, n_block=nb)
    np.testing.assert_array_equal(np.asarray(blk), np.asarray(plain))


def test_blocked_matches_ref_directly():
    x = rand((8, 9, 9), 0, 256, 1)
    w = rand((8, 8, 3, 3), -16, 16, 2)
    got = blocked.trim_conv3d_blocked(x, w, m_block=4, n_block=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(conv3d_ref(x, w)))


@settings(deadline=None, max_examples=12)
@given(
    m=st.sampled_from([2, 4, 8]),
    n=st.sampled_from([2, 4, 8]),
    h=st.integers(5, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_blocked_hypothesis_sweep(m, n, h, seed):
    x = rand((m, h, h), 0, 256, seed)
    w = rand((n, m, 3, 3), -8, 8, seed ^ 0xFF)
    got = blocked.trim_conv3d_blocked(x, w, m_block=min(2, m), n_block=min(2, n))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(conv3d_ref(x, w)))


def test_blocked_rejects_nondivisible():
    x = rand((6, 8, 8), 0, 256, 3)
    w = rand((4, 6, 3, 3), -8, 8, 4)
    with pytest.raises(AssertionError):
        blocked.trim_conv3d_blocked(x, w, m_block=4, n_block=4)


def test_maxpool2_pallas_matches_model():
    x = rand((3, 8, 10), 0, 256, 5)
    got = blocked.maxpool2_pallas(x)
    ref = model.maxpool2(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_maxpool2_pallas_requires_even_dims():
    with pytest.raises(AssertionError):
        blocked.maxpool2_pallas(jnp.ones((1, 5, 6), jnp.int32))
