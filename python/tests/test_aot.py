"""AOT path: artifacts lower to loadable HLO text with a correct manifest."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    aot.build_artifacts(str(d))
    return str(d)


EXPECTED = ["trimnet_block0", "trimnet_block1", "trimnet_block2", "trimnet_head", "trimnet_full", "conv_unit"]


def test_all_artifacts_emitted(artifact_dir):
    for name in EXPECTED:
        path = os.path.join(artifact_dir, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text


def test_manifest_covers_all_artifacts(artifact_dir):
    lines = open(os.path.join(artifact_dir, "manifest.txt")).read().splitlines()
    assert lines[0].startswith("#")
    names = [l.split()[1] for l in lines[1:]]
    assert sorted(names) == sorted(EXPECTED)
    for l in lines[1:]:
        fields = dict(kv.split("=", 1) for kv in l.split()[2:])
        assert set(fields) == {"file", "inputs", "outputs"}
        for io in fields["inputs"].split(","):
            dtype, shape = io.split(":")
            assert dtype == "i32"
            assert all(int(d) > 0 for d in shape.split("x"))


def test_artifact_roundtrip_executes_on_cpu_pjrt(artifact_dir):
    """Compile the block0 HLO with the local CPU client and compare against
    the L2 model — the exact check the Rust runtime repeats natively."""
    from jax._src.lib import xla_client as xc

    text = open(os.path.join(artifact_dir, "trimnet_block0.hlo.txt")).read()
    # HLO text → computation → executable on the CPU PJRT client.
    comp = xc._xla.hlo_module_from_text(text)
    rng = np.random.default_rng(3)
    x = rng.integers(0, 256, size=model.TRIMNET_INPUT).astype(np.int32)

    ws, _ = model.trimnet_weights(seed=0)
    expect = model.trimnet_block(jnp.asarray(x), ws[0], model.TRIMNET_SPECS[0])

    client = xc._xla.get_local_backend("cpu") if hasattr(xc._xla, "get_local_backend") else None
    if client is None:
        pytest.skip("no direct local backend accessor in this jaxlib")
    loaded = client.compile(xc._xla.XlaComputation(comp.as_serialized_hlo_module_proto()))
    out = loaded.execute([client.buffer_from_pyval(x)])
    got = np.asarray(out[0][0] if isinstance(out[0], (list, tuple)) else out[0])
    np.testing.assert_array_equal(got, np.asarray(expect))
