"""Layer-2: quantised CNN layers built on the TrIM Pallas kernels.

Build-time only — this module is lowered once by `aot.py` to HLO text and
never imported at runtime. The Rust coordinator executes the lowered
artifacts through PJRT.

The data representation matches the paper (§III-A) and the Rust engine:
uint8 activations and int8 weights carried as int32 at the XLA boundary,
int32 accumulation, power-of-two re-quantisation between layers
(bit-exact with `rust/src/model/quant.rs::Requant`).
"""

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from compile.kernels import trim_conv
from compile.kernels.ref import pad_hw, requant_ref


def conv_layer(x, w, *, pad: int = 1, shift: int = 7, bits: int = 8, interpret: bool = True):
    """One quantised convolutional layer: pad → TrIM conv → requantise.

    Args:
      x: (M, H, W) int32 activations in [0, 2^bits).
      w: (N, M, K, K) int32 signed weights.
      pad: zero padding per border.
      shift: power-of-two re-quantisation shift.

    Returns:
      (N, H_O, W_O) int32 activations in [0, 2^bits).
    """
    xp = pad_hw(x, pad)
    acc = trim_conv.trim_conv3d(xp, w, interpret=interpret)
    return requant_ref(acc, shift, bits)


def maxpool2(x):
    """2×2 max pooling on (C, H, W) (AlexNet/VGG-style downsampling)."""
    c, h, w = x.shape
    x = x[:, : h // 2 * 2, : w // 2 * 2]
    x = x.reshape(c, h // 2, 2, w // 2, 2)
    return jnp.max(x, axis=(2, 4))


def head(x, w_fc):
    """Classifier head: global average pool + integer matmul.

    Args:
      x: (C, H, W) int32 activations.
      w_fc: (C, n_classes) int32 weights.

    Returns:
      (n_classes,) int32 logits.
    """
    c = x.shape[0]
    pooled = jnp.sum(x.reshape(c, -1), axis=1, dtype=jnp.int32)  # sum-pool (integer)
    return pooled @ w_fc


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Static description of one TrimNet conv layer."""

    m: int
    n: int
    k: int = 3
    pad: int = 1
    shift: int = 7
    pool: bool = True


# The e2e workload: a small integer CNN on 3×32×32 inputs (CIFAR-sized),
# structurally a miniature VGG — three 3×3 conv blocks with 2× pooling.
TRIMNET_SPECS: Sequence[ConvSpec] = (
    ConvSpec(m=3, n=16, shift=6),
    ConvSpec(m=16, n=32, shift=8),
    ConvSpec(m=32, n=64, shift=9),
)
TRIMNET_INPUT = (3, 32, 32)
TRIMNET_CLASSES = 10


def trimnet_block(x, w, spec: ConvSpec, *, interpret: bool = True):
    """One TrimNet block: conv → requant → optional 2×2 maxpool."""
    y = conv_layer(x, w, pad=spec.pad, shift=spec.shift, interpret=interpret)
    return maxpool2(y) if spec.pool else y


def trimnet_forward(x, conv_ws, w_fc, *, interpret: bool = True):
    """Full TrimNet forward pass: 3 conv blocks + classifier head."""
    for w, spec in zip(conv_ws, TRIMNET_SPECS):
        x = trimnet_block(x, w, spec, interpret=interpret)
    return head(x, w_fc)


def trimnet_weights(seed: int = 0):
    """Deterministic synthetic int8 weights for TrimNet."""
    key = jax.random.PRNGKey(seed)
    ws = []
    for spec in TRIMNET_SPECS:
        key, sub = jax.random.split(key)
        ws.append(jax.random.randint(sub, (spec.n, spec.m, spec.k, spec.k), -8, 8, dtype=jnp.int32))
    key, sub = jax.random.split(key)
    w_fc = jax.random.randint(sub, (TRIMNET_SPECS[-1].n, TRIMNET_CLASSES), -8, 8, dtype=jnp.int32)
    return ws, w_fc


def block_io_shapes():
    """(input_shape, output_shape) per TrimNet block plus the head —
    the shape contract consumed by the Rust runtime's artifact manifest."""
    shapes = []
    c, h, w = TRIMNET_INPUT
    for spec in TRIMNET_SPECS:
        out = (spec.n, h // 2, w // 2)
        shapes.append(((spec.m, h, w), out))
        c, h, w = out
    shapes.append(((c, h, w), (TRIMNET_CLASSES,)))
    return shapes
