"""Pure-jnp oracle for the TrIM convolution kernels.

This is the correctness reference (the analogue of the Rust golden model):
direct integer convolution with int32 accumulation, matching the paper's
datapath — B-bit unsigned inputs, B-bit signed weights, `2B+K+log`-bit
signed psums (all carried in int32, which is wide enough for B = 8, K = 3,
M ≤ 512; see DESIGN.md §2).
"""

import jax.numpy as jnp


def conv2d_ref(x, w, stride: int = 1):
    """Direct 2-D convolution, 'valid' (pad outside if needed).

    Args:
      x: (H, W) integer ifmap (already padded).
      w: (K, K) integer kernel.
      stride: output stride.

    Returns:
      (H_O, W_O) int32 ofmap.
    """
    h, ww = x.shape
    k = w.shape[0]
    h_o = (h - k) // stride + 1
    w_o = (ww - k) // stride + 1
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    out = jnp.zeros((h_o, w_o), jnp.int32)
    for r in range(k):
        for c in range(k):
            patch = x[r : r + (h_o - 1) * stride + 1 : stride, c : c + (w_o - 1) * stride + 1 : stride]
            out = out + patch * w[r, c]
    return out


def conv3d_ref(x, w, stride: int = 1):
    """Multi-channel multi-filter direct convolution.

    Args:
      x: (M, H, W) integer ifmaps (already padded).
      w: (N, M, K, K) integer filters.
      stride: output stride.

    Returns:
      (N, H_O, W_O) int32 ofmaps.
    """
    m, h, ww = x.shape
    n, m2, k, _ = w.shape
    assert m == m2, f"channel mismatch {m} vs {m2}"
    h_o = (h - k) // stride + 1
    w_o = (ww - k) // stride + 1
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    out = jnp.zeros((n, h_o, w_o), jnp.int32)
    for r in range(k):
        for c in range(k):
            patch = x[:, r : r + (h_o - 1) * stride + 1 : stride, c : c + (w_o - 1) * stride + 1 : stride]
            # (N, M) · (M, H_O, W_O) contraction over channels
            out = out + jnp.einsum("nm,mhw->nhw", w[:, :, r, c], patch).astype(jnp.int32)
    return out


def pad_hw(x, pad: int):
    """Zero-pad the trailing two (spatial) dims by `pad` on each border."""
    if pad == 0:
        return x
    cfg = [(0, 0)] * (x.ndim - 2) + [(pad, pad), (pad, pad)]
    return jnp.pad(x, cfg)


def requant_ref(acc, shift: int, bits: int = 8):
    """Power-of-two re-quantisation: clamp(round_half_up(acc / 2^shift)).

    Bit-exact twin of `rust/src/model/quant.rs::Requant`.
    """
    half = 0 if shift == 0 else (1 << (shift - 1))
    y = jnp.right_shift(acc + half, shift)
    return jnp.clip(y, 0, (1 << bits) - 1).astype(jnp.int32)
