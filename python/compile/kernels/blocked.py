"""Blocked TrIM kernels: the MXU-oriented variants.

`trim_conv3d` in trim_conv.py maps one filter per grid step (the engine's
P_N cores) with the full channel window resident. For large M/N that
working set exceeds VMEM and the per-tap contraction is a skinny (1, M)
matvec — poor MXU shaping. The blocked variant restores both:

* the grid carries an explicit **filter-block** dimension (P_N-like) and a
  **channel-block** loop (P_M-like), so the resident set per step is
  `(M_B, K, W_P)` inputs + `(N_B, M_B, K, K)` weights — the TrIM engine's
  step structure, literally;
* each tap contraction is an `(N_B, M_B) × (M_B, W_O)` matmul — MXU-shaped
  when the blocks are ≥ 8 (128 on real hardware).

The channel-block accumulation uses the output ref as the psum buffer
(revisited across grid steps) — the AOT analogue of the engine's temporal
accumulation (Fig. 6).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blocked_kernel(x_ref, w_ref, o_ref, *, k: int, w_o: int, m_b: int, n_b: int):
    """Grid = (N/N_B, M/M_B, H_O). One output-row block for one filter
    block, accumulating one channel block into the psum (output) ref.

    x_ref: (M_B, H_P, W_P) — this channel block's padded ifmaps.
    w_ref: (N_B, M_B, K, K) — this (filter, channel) weight block.
    o_ref: (N_B, 1, W_O) — psum rows, revisited across channel blocks.
    """
    mi = pl.program_id(1)
    oy = pl.program_id(2)
    w_p = x_ref.shape[2]
    window = pl.load(x_ref, (pl.dslice(0, m_b), pl.dslice(oy, k), pl.dslice(0, w_p)))

    acc = jnp.zeros((n_b, w_o), jnp.int32)
    for r in range(k):
        rows = window[:, r, :]  # (M_B, W_P)
        for c in range(k):
            win = jax.lax.dynamic_slice(rows, (0, c), (m_b, w_o))  # (M_B, W_O)
            taps = w_ref[:, :, r, c]  # (N_B, M_B)
            # MXU-shaped contraction: (N_B, M_B) @ (M_B, W_O)
            acc = acc + jax.lax.dot(taps, win, preferred_element_type=jnp.int32)

    # temporal accumulation across channel blocks (engine psum buffers)
    prev = jnp.where(mi == 0, jnp.zeros_like(acc), o_ref[:, 0, :])
    o_ref[:, 0, :] = prev + acc


def trim_conv3d_blocked(x, w, *, m_block: int = 8, n_block: int = 8, interpret: bool = True):
    """Blocked multi-channel convolution (stride 1, pre-padded).

    Args:
      x: (M, H_P, W_P) int32 padded ifmaps; M must divide by m_block.
      w: (N, M, K, K) int32 filters; N must divide by n_block.

    Returns:
      (N, H_O, W_O) int32 — identical to `trim_conv.trim_conv3d`.
    """
    m, h_p, w_p = x.shape
    n, m2, k, _ = w.shape
    assert m == m2
    m_block = min(m_block, m)
    n_block = min(n_block, n)
    assert m % m_block == 0, f"M={m} not divisible by m_block={m_block}"
    assert n % n_block == 0, f"N={n} not divisible by n_block={n_block}"
    h_o, w_o = h_p - k + 1, w_p - k + 1
    kernel = functools.partial(_blocked_kernel, k=k, w_o=w_o, m_b=m_block, n_b=n_block)
    return pl.pallas_call(
        kernel,
        grid=(n // n_block, m // m_block, h_o),
        in_specs=[
            pl.BlockSpec((m_block, h_p, w_p), lambda f, mi, oy: (mi, 0, 0)),
            pl.BlockSpec((n_block, m_block, k, k), lambda f, mi, oy: (f, mi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n_block, 1, w_o), lambda f, mi, oy: (f, oy, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_o, w_o), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.int32), w.astype(jnp.int32))


def _maxpool2_kernel(x_ref, o_ref):
    """2×2 max pool of one channel row-pair. x: (1, 2, W), o: (1, 1, W/2)."""
    rows = x_ref[0]  # (2, W)
    w = rows.shape[1]
    pairs = jnp.maximum(rows[0], rows[1])  # vertical max
    o_ref[0, 0, :] = jnp.maximum(pairs[0 : w - 1 : 2], pairs[1:w:2])  # horizontal


def maxpool2_pallas(x, *, interpret: bool = True):
    """2×2 max pooling on (C, H, W) with the same row-walking grid shape
    as the conv kernels (C × H/2 steps)."""
    c, h, w = x.shape
    assert h % 2 == 0 and w % 2 == 0, "pool needs even spatial dims"
    return pl.pallas_call(
        _maxpool2_kernel,
        grid=(c, h // 2),
        in_specs=[pl.BlockSpec((1, 2, w), lambda ci, oy: (ci, oy, 0))],
        out_specs=pl.BlockSpec((1, 1, w // 2), lambda ci, oy: (ci, oy, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h // 2, w // 2), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.int32))
