"""Layer-1 Pallas kernels: the TrIM dataflow re-thought for a TPU-style
memory hierarchy.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the FPGA slice keeps
K ifmap rows alive in shift registers (RSRBs) and streams one window per
cycle. On TPU the analogous schedule is:

* the **grid walks output rows** — the diagonal movement. Each grid step
  `oy` consumes a `(K, W_P)` row window of the padded ifmap (the RSRB
  working set), taken with a dynamic slice so consecutive steps overlap by
  K−1 rows exactly like the RSRB replay;
* **weight stationarity** — the `(K, K)` (or `(N, M, K, K)`) weight block
  is mapped whole to every grid step, so it stays VMEM-resident for the
  entire convolution, like the PE weight registers;
* the **horizontal movement** becomes lane-parallel shifted-slice MACs
  along the row (the vector unit consumes the window overlap that the FPGA
  consumed via right-to-left pass registers);
* the K×K tap accumulation happens in registers — the vertical psum chain.

Kernels run with `interpret=True`: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and numerics are identical (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv2d_row_kernel(x_ref, w_ref, o_ref, *, k: int, w_o: int):
    """One output row of a K×K convolution.

    x_ref: (H_P, W_P) padded ifmap (whole); the kernel reads only the
           K-row window starting at `oy` — the RSRB working set.
    w_ref: (K, K) stationary weights.
    o_ref: (1, W_O) the produced output row.
    """
    oy = pl.program_id(0)
    w_p = x_ref.shape[1]
    window = pl.load(x_ref, (pl.dslice(oy, k), pl.dslice(0, w_p)))  # (K, W_P)
    acc = jnp.zeros((w_o,), jnp.int32)
    for r in range(k):
        row = window[r, :]
        for c in range(k):
            # shifted-slice MAC: the lane dimension carries the
            # horizontal (right-to-left) reuse of the FPGA slice
            acc = acc + jax.lax.dynamic_slice(row, (c,), (w_o,)) * w_ref[r, c]
    o_ref[0, :] = acc


def trim_conv2d(x, w, *, interpret: bool = True):
    """2-D K×K convolution over an already-padded ifmap (stride 1).

    Args:
      x: (H_P, W_P) int32 padded ifmap.
      w: (K, K) int32 kernel.

    Returns:
      (H_O, W_O) int32 ofmap, H_O = H_P-K+1, W_O = W_P-K+1.
    """
    h_p, w_p = x.shape
    k = w.shape[0]
    h_o, w_o = h_p - k + 1, w_p - k + 1
    kernel = functools.partial(_conv2d_row_kernel, k=k, w_o=w_o)
    return pl.pallas_call(
        kernel,
        grid=(h_o,),
        in_specs=[
            pl.BlockSpec((h_p, w_p), lambda oy: (0, 0)),  # resident ifmap
            pl.BlockSpec((k, k), lambda oy: (0, 0)),  # stationary weights
        ],
        out_specs=pl.BlockSpec((1, w_o), lambda oy: (oy, 0)),
        out_shape=jax.ShapeDtypeStruct((h_o, w_o), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.int32), w.astype(jnp.int32))


def _conv3d_row_kernel(x_ref, w_ref, o_ref, *, k: int, w_o: int, m: int):
    """One output row for one filter, contracted over all M channels.

    x_ref: (M, H_P, W_P) padded ifmaps (whole); reads the (M, K, W_P)
           window at `oy` — the P_M slices' RSRB working sets side by side.
    w_ref: (1, M, K, K) — the filter owned by this "core".
    o_ref: (1, 1, W_O)
    """
    oy = pl.program_id(1)
    w_p = x_ref.shape[2]
    window = pl.load(x_ref, (pl.dslice(0, m), pl.dslice(oy, k), pl.dslice(0, w_p)))
    acc = jnp.zeros((w_o,), jnp.int32)
    for r in range(k):
        rows = window[:, r, :]  # (M, W_P)
        for c in range(k):
            win = jax.lax.dynamic_slice(rows, (0, c), (m, w_o))  # (M, W_O)
            taps = w_ref[0, :, r, c]  # (M,)
            # channel contraction = the core adder tree (MXU-shaped when
            # M is large: a (1,M)x(M,W_O) matmul per tap)
            acc = acc + jnp.sum(win * taps[:, None], axis=0, dtype=jnp.int32)
    o_ref[0, 0, :] = acc


def trim_conv3d(x, w, *, interpret: bool = True):
    """Multi-channel, multi-filter convolution (stride 1, pre-padded).

    Grid = (N, H_O): filters map to the engine's P_N cores, output rows to
    the temporal schedule of each slice.

    Args:
      x: (M, H_P, W_P) int32 padded ifmaps.
      w: (N, M, K, K) int32 filters.

    Returns:
      (N, H_O, W_O) int32 ofmaps.
    """
    m, h_p, w_p = x.shape
    n, m2, k, _ = w.shape
    assert m == m2
    h_o, w_o = h_p - k + 1, w_p - k + 1
    kernel = functools.partial(_conv3d_row_kernel, k=k, w_o=w_o, m=m)
    return pl.pallas_call(
        kernel,
        grid=(n, h_o),
        in_specs=[
            pl.BlockSpec((m, h_p, w_p), lambda f, oy: (0, 0, 0)),  # broadcast ifmaps
            pl.BlockSpec((1, m, k, k), lambda f, oy: (f, 0, 0, 0)),  # core f's filter
        ],
        out_specs=pl.BlockSpec((1, 1, w_o), lambda f, oy: (f, oy, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_o, w_o), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.int32), w.astype(jnp.int32))


def vmem_footprint_bytes(m: int, w_p: int, n: int, k: int) -> int:
    """Estimated VMEM working set per grid step of `trim_conv3d`:
    the (M, K, W_P) input window + one (M, K, K) filter + the (W_O,)
    accumulator, in int32. Used by the DESIGN.md §Perf roofline estimate
    (interpret-mode wall clock is NOT a TPU proxy).
    """
    del n  # one filter resident per step
    w_o = w_p - k + 1
    words = m * k * w_p + m * k * k + w_o
    return 4 * words
