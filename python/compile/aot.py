"""AOT compile path: lower the L2 model to HLO *text* artifacts.

Run once by `make artifacts`; the Rust binary is self-contained afterwards.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all int32 at the boundary):

* ``trimnet_block{0,1,2}.hlo.txt`` — one per TrimNet conv block, weights
  baked in as constants (the AOT equivalent of TrIM's weight-stationarity:
  weights are loaded at compile time, activations stream at run time);
* ``trimnet_head.hlo.txt`` — classifier head;
* ``trimnet_full.hlo.txt`` — whole forward pass (cross-check artifact);
* ``conv_unit.hlo.txt`` — small `conv_layer` with *runtime* weights, used
  by the Rust test suite to validate PJRT numerics against the golden
  model;
* ``manifest.txt`` — shape contract parsed by ``rust/src/runtime``.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.ref import pad_hw


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_fn(fn, *example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def spec_i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def shape_str(shape):
    return "x".join(str(d) for d in shape) if shape else "scalar"


def build_artifacts(out_dir: str, interpret: bool = True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = ["# trim-sa artifact manifest v1"]
    conv_ws, w_fc = model.trimnet_weights(seed=0)

    def emit(name, fn, arg_shapes, out_shape):
        text = lower_fn(fn, *[spec_i32(s) for s in arg_shapes])
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        ins = ",".join(f"i32:{shape_str(s)}" for s in arg_shapes)
        manifest.append(f"artifact {name} file={name}.hlo.txt inputs={ins} outputs=i32:{shape_str(out_shape)}")
        print(f"  {name}: {len(text)} chars, in={ins} out={shape_str(out_shape)}")

    # --- per-block serving artifacts (weights baked = weight-stationary) ---
    io_shapes = model.block_io_shapes()
    for i, spec in enumerate(model.TRIMNET_SPECS):
        w = conv_ws[i]
        fn = functools.partial(
            lambda x, w=w, spec=spec: (model.trimnet_block(x, w, spec, interpret=interpret),)
        )
        in_shape, out_shape = io_shapes[i]
        emit(f"trimnet_block{i}", fn, [in_shape], out_shape)

    head_in, head_out = io_shapes[-1]
    emit("trimnet_head", lambda x: (model.head(x, w_fc),), [head_in], head_out)

    # --- whole-network cross-check artifact ---
    emit(
        "trimnet_full",
        lambda x: (model.trimnet_forward(x, conv_ws, w_fc, interpret=interpret),),
        [model.TRIMNET_INPUT],
        (model.TRIMNET_CLASSES,),
    )

    # --- runtime-weight conv for Rust-side numeric validation ---
    def conv_unit(x, w):
        acc = __import__("compile.kernels.trim_conv", fromlist=["trim_conv3d"]).trim_conv3d(
            pad_hw(x, 1), w, interpret=interpret
        )
        return (acc,)

    emit("conv_unit", conv_unit, [(2, 8, 8), (3, 2, 3, 3)], (3, 8, 8))

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest) - 1} artifacts to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    build_artifacts(args.out_dir)


if __name__ == "__main__":
    main()
